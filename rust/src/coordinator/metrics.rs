//! Serving metrics: per-route latency decomposition.
//!
//! Mirrors the paper's measurement protocol — every request records
//! queueing delay, launch (dispatch) estimate and execution wall time,
//! so the serving path can regenerate the §6.1 tables without a separate
//! instrumentation harness.  Queue-delay percentiles (p50/p95/p99) are
//! exported per route (exact over the raw samples; `stats::Histogram`
//! serves the distribution view), padded batch slots are counted so the
//! batcher's padding waste is visible next to its launch-amortisation
//! win, and shed requests (SLO admission control, `service.rs`) are
//! counted next to the demand they were shed from.
//!
//! All time enters as [`Timestamp`]s from the injected clock — the
//! registry itself never reads wall time, so a simulated run produces
//! bit-identical tables.
//!
//! Under the *stealing* scheduler (DESIGN.md §12) the registry also
//! keeps per-worker counters — launches, busy time, utilization over
//! the observed span, steals and ownership migrations — rendered as a
//! second table section, so the load-balancing claim is observable in
//! a live `serve-demo` run.  The pinned scheduler records none of
//! these, keeping its table bit-identical to PR 2.

use std::collections::{HashMap, VecDeque};
use std::time::Duration;

use super::clock::Timestamp;
use super::completion::CompletionStats;
use super::RouteKey;
use crate::fft::PlannerStats;
use crate::stats::{percentile_sorted, Histogram, Summary};

/// Retention cap per sample series: beyond this the oldest half is
/// dropped, so a long-running serve loop keeps a bounded, recent window
/// (summaries and percentiles then describe current behaviour, and the
/// per-flush sort stays O(cap log cap)).  Counters are never trimmed.
pub const MAX_SAMPLES_PER_KEY: usize = 16_384;

/// Sample-count cap on the SLO sliding window (admission control looks
/// at a *time* window; this bounds its memory under extreme rates).
const SLO_WINDOW_CAP: usize = 1_024;

/// The admission controller only trusts a sliding-window p99 computed
/// from at least this many samples; below it, requests are admitted.
/// This is also what re-opens a route after an overload: once the bad
/// samples age out of the time window, the gate lifts.
pub const SLO_MIN_SAMPLES: usize = 8;

/// Accumulated samples for one routing key.
#[derive(Clone, Debug, Default)]
pub struct KeyMetrics {
    pub requests: u64,
    pub launches: u64,
    pub batched_requests: u64,
    /// Batch slots launched without a request in them (zero padding).
    pub padded_slots: u64,
    /// Submissions rejected by the SLO admission controller.
    pub shed_requests: u64,
    pub queue_us: Vec<f64>,
    pub exec_us: Vec<f64>,
    /// Launch-stamped queue-delay samples for the SLO sliding window.
    recent_queue: VecDeque<(Timestamp, f64)>,
    /// Memoised sliding-window p99, invalidated whenever the window's
    /// contents change (new launch samples or time-based eviction), so
    /// the per-submit admission check is O(1) between launches instead
    /// of a sort under the shared metrics mutex.
    slo_p99_cache: Option<f64>,
}

impl KeyMetrics {
    /// Requests amortised per launch (the batcher's win).
    pub fn amortisation(&self) -> f64 {
        if self.launches == 0 {
            0.0
        } else {
            self.requests as f64 / self.launches as f64
        }
    }

    pub fn exec_summary(&self) -> Option<Summary> {
        if self.exec_us.is_empty() {
            None
        } else {
            Some(Summary::from_samples(&self.exec_us))
        }
    }

    pub fn queue_summary(&self) -> Option<Summary> {
        if self.queue_us.is_empty() {
            None
        } else {
            Some(Summary::from_samples(&self.queue_us))
        }
    }

    /// Queue-delay `(p50, p95, p99)` in microseconds, exact over the
    /// recorded samples.
    ///
    /// Exact-on-raw-samples, not binned: a uniform-bin
    /// [`Histogram::percentile`] is only accurate to one bin width, and
    /// one long-tail outlier (a stall, a cold lowering) stretches the
    /// range until every bin is wider than the entire typical
    /// distribution — precisely when the percentiles matter most.  The
    /// registry already keeps the raw samples, so exactness is free;
    /// the histogram stays the tool for the *distribution* displays.
    pub fn queue_percentiles(&self) -> Option<(f64, f64, f64)> {
        if self.queue_us.is_empty() {
            return None;
        }
        let mut sorted = self.queue_us.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some((
            percentile_sorted(&sorted, 50.0),
            percentile_sorted(&sorted, 95.0),
            percentile_sorted(&sorted, 99.0),
        ))
    }

    /// Queue-delay distribution as a fixed-bin [`Histogram`] (the Fig. 6
    /// style display; `None` until a launch is recorded).  Log-spaced
    /// bins: queue delays are heavy-tailed, and uniform bins lose the
    /// entire bulk of the distribution to one stall outlier (see the
    /// accuracy study in `stats::histogram`).
    pub fn queue_histogram(&self, bins: usize) -> Option<Histogram> {
        if self.queue_us.is_empty() {
            None
        } else {
            Some(Histogram::log_from_samples(&self.queue_us, bins))
        }
    }

    /// Queue-delay p99 over the sliding `window` ending at `now` —
    /// the admission controller's view.  `None` while the window holds
    /// fewer than [`SLO_MIN_SAMPLES`] samples.
    pub fn sliding_queue_p99(&mut self, now: Timestamp, window: Duration) -> Option<f64> {
        while let Some(&(stamp, _)) = self.recent_queue.front() {
            if now.saturating_since(stamp) > window {
                self.recent_queue.pop_front();
                self.slo_p99_cache = None;
            } else {
                break;
            }
        }
        if self.recent_queue.len() < SLO_MIN_SAMPLES {
            return None;
        }
        if let Some(p99) = self.slo_p99_cache {
            return Some(p99);
        }
        let mut sorted: Vec<f64> = self.recent_queue.iter().map(|&(_, q)| q).collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = percentile_sorted(&sorted, 99.0);
        self.slo_p99_cache = Some(p99);
        Some(p99)
    }
}

/// Per-worker execution counters, recorded only by the stealing
/// scheduler (the pinned path stays bit-identical to PR 2).
#[derive(Clone, Debug, Default)]
pub struct WorkerMetrics {
    /// Launches this worker executed.
    pub launches: u64,
    /// Total execution time on the injected clock [us].
    pub busy_us: f64,
    /// Whole-route steals this worker performed (as the thief).
    pub steals: u64,
    /// Placement-time ownership migrations onto this worker.
    pub migrations: u64,
}

/// Registry over all keys.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    by_key: HashMap<RouteKey, KeyMetrics>,
    /// Per-worker counters (stealing scheduler only; empty — and the
    /// table section absent — under the pinned scheduler).
    workers: Vec<WorkerMetrics>,
    /// First/last launch stamp across all workers: the span utilization
    /// is computed over.
    worker_span: Option<(Timestamp, Timestamp)>,
    /// Latest snapshot of the plan-cache counters (see
    /// `fft::FftPlanner`), rendered as a table footer.
    planner: Option<PlannerStats>,
    /// Latest completion-queue snapshot (ticket fan-in surface,
    /// DESIGN.md §18), rendered as a footer.  The leader only attaches
    /// it once a ticket has been opened, so blocking-only runs render
    /// byte-identical tables.
    completion: Option<CompletionStats>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Attach the latest planner cache counters.
    pub fn set_planner_stats(&mut self, stats: PlannerStats) {
        self.planner = Some(stats);
    }

    pub fn planner_stats(&self) -> Option<PlannerStats> {
        self.planner
    }

    /// Attach the latest completion-queue snapshot (in-flight depth and
    /// reap-batch-size histograms included).
    pub fn set_completion_stats(&mut self, stats: CompletionStats) {
        self.completion = Some(stats);
    }

    pub fn completion_stats(&self) -> Option<&CompletionStats> {
        self.completion.as_ref()
    }

    /// Record one launch of an `artifact_batch`-sized artifact carrying
    /// `members` requests (slots beyond `members` were zero padding),
    /// issued at `now` on the injected clock.
    pub fn record_launch(
        &mut self,
        key: RouteKey,
        members: usize,
        artifact_batch: usize,
        exec_us: f64,
        queue_us: &[f64],
        now: Timestamp,
    ) {
        let m = self.by_key.entry(key).or_default();
        m.launches += 1;
        m.requests += members as u64;
        if members > 1 {
            m.batched_requests += members as u64;
        }
        m.padded_slots += artifact_batch.saturating_sub(members) as u64;
        m.exec_us.push(exec_us);
        m.queue_us.extend_from_slice(queue_us);
        if !queue_us.is_empty() {
            m.slo_p99_cache = None;
        }
        for &q in queue_us {
            m.recent_queue.push_back((now, q));
        }
        while m.recent_queue.len() > SLO_WINDOW_CAP {
            m.recent_queue.pop_front();
        }
        for series in [&mut m.exec_us, &mut m.queue_us] {
            if series.len() > MAX_SAMPLES_PER_KEY {
                series.drain(..series.len() - MAX_SAMPLES_PER_KEY / 2);
            }
        }
    }

    /// Count one submission rejected by the SLO admission controller.
    pub fn record_shed(&mut self, key: RouteKey) {
        self.by_key.entry(key).or_default().shed_requests += 1;
    }

    /// Declare the pool size up front (stealing scheduler only), so
    /// the table shows a row for every worker — an idle worker at 0%
    /// utilization is exactly what the load-balance section must make
    /// visible, and lazy resizing would silently omit trailing ones.
    pub fn set_worker_count(&mut self, workers: usize) {
        if self.workers.len() < workers {
            self.workers.resize(workers, WorkerMetrics::default());
        }
    }

    fn worker_mut(&mut self, worker: usize) -> &mut WorkerMetrics {
        if self.workers.len() <= worker {
            self.workers.resize(worker + 1, WorkerMetrics::default());
        }
        &mut self.workers[worker]
    }

    /// Attribute one launch (already counted via [`record_launch`]) to
    /// a pool worker — stealing scheduler only.
    ///
    /// The utilization span runs from the first launch's *start* to the
    /// last launch's *completion* (start + execution time): ending it
    /// at the last start would exclude busy time the numerator counts
    /// and report a saturated worker above 100%.
    ///
    /// [`record_launch`]: MetricsRegistry::record_launch
    pub fn record_worker_launch(&mut self, worker: usize, exec_us: f64, now: Timestamp) {
        let w = self.worker_mut(worker);
        w.launches += 1;
        w.busy_us += exec_us;
        let end = now + Duration::from_nanos((exec_us * 1e3).max(0.0) as u64);
        self.worker_span = Some(match self.worker_span {
            None => (now, end),
            Some((first, last)) => (first.min(now), last.max(end)),
        });
    }

    /// Count one whole-route steal performed by `thief`.
    pub fn record_steal(&mut self, thief: usize) {
        self.worker_mut(thief).steals += 1;
    }

    /// Count one placement-time ownership migration onto `worker`.
    pub fn record_migration(&mut self, worker: usize) {
        self.worker_mut(worker).migrations += 1;
    }

    /// Per-worker counters (empty under the pinned scheduler).
    pub fn workers(&self) -> &[WorkerMetrics] {
        &self.workers
    }

    /// The admission controller's question: is this route's sliding
    /// queue-delay p99 over budget at `now`?
    pub fn over_slo(
        &mut self,
        key: &RouteKey,
        now: Timestamp,
        window: Duration,
        budget_us: f64,
    ) -> bool {
        match self.by_key.get_mut(key) {
            Some(m) => m.sliding_queue_p99(now, window).is_some_and(|p99| p99 > budget_us),
            None => false,
        }
    }

    pub fn get(&self, key: &RouteKey) -> Option<&KeyMetrics> {
        self.by_key.get(key)
    }

    pub fn keys(&self) -> Vec<RouteKey> {
        let mut v: Vec<RouteKey> = self.by_key.keys().copied().collect();
        v.sort_by_key(|k| (k.n, k.variant.name(), k.direction.name(), k.kind.name()));
        v
    }

    pub fn total_requests(&self) -> u64 {
        self.by_key.values().map(|m| m.requests).sum()
    }

    pub fn total_launches(&self) -> u64 {
        self.by_key.values().map(|m| m.launches).sum()
    }

    pub fn total_padded_slots(&self) -> u64 {
        self.by_key.values().map(|m| m.padded_slots).sum()
    }

    pub fn total_shed_requests(&self) -> u64 {
        self.by_key.values().map(|m| m.shed_requests).sum()
    }

    pub fn total_steals(&self) -> u64 {
        self.workers.iter().map(|w| w.steals).sum()
    }

    pub fn total_migrations(&self) -> u64 {
        self.workers.iter().map(|w| w.migrations).sum()
    }

    /// Render an aligned text table (one row per key).
    pub fn render_table(&self) -> String {
        let mut out = String::from(
            "route                          reqs  launches  reqs/launch  padded    shed  \
             exec-mean[us]  q-p50[us]  q-p95[us]  q-p99[us]\n",
        );
        for key in self.keys() {
            let m = &self.by_key[&key];
            let s = m.exec_summary();
            let (p50, p95, p99) = m.queue_percentiles().unwrap_or((0.0, 0.0, 0.0));
            out.push_str(&format!(
                "{:<28} {:>6} {:>9} {:>12.2} {:>7} {:>7} {:>14.1} {:>10.1} {:>10.1} {:>10.1}\n",
                key.label(),
                m.requests,
                m.launches,
                m.amortisation(),
                m.padded_slots,
                m.shed_requests,
                s.map_or(0.0, |s| s.mean),
                p50,
                p95,
                p99,
            ));
        }
        if !self.workers.is_empty() {
            // Stealing-scheduler section: per-worker load balance.
            // Utilization is busy time over the first-to-last launch
            // span on the injected clock (0 when the span is empty —
            // e.g. a simulated run that never advanced time).
            let span_us = self.worker_span.map_or(0.0, |(first, last)| last.micros_since(first));
            out.push_str("worker      launches  busy[us]    util[%]  steals  migrations\n");
            for (i, w) in self.workers.iter().enumerate() {
                let util = if span_us > 0.0 { 100.0 * w.busy_us / span_us } else { 0.0 };
                out.push_str(&format!(
                    "w{i:<10} {:>8} {:>9.1} {:>10.1} {:>7} {:>11}\n",
                    w.launches, w.busy_us, util, w.steals, w.migrations,
                ));
            }
        }
        if let Some(p) = self.planner {
            out.push_str(&format!(
                "plan cache: {} cached (cap {}), {} hits / {} misses ({:.1}% hit rate), {} evictions\n",
                p.cached,
                p.capacity,
                p.hits,
                p.misses,
                100.0 * p.hit_rate(),
                p.evictions,
            ));
        }
        if let Some(c) = &self.completion {
            out.push_str(&format!(
                "completion queue: {} slots (high water {}), {} opened / {} reaped, {} in flight\n",
                c.slots, c.high_water, c.opened, c.reaped, c.in_flight,
            ));
            out.push_str(&format!(
                "completion reaps: {} wakeups, mean batch {:.2}, depth p50 ~{}, reap p50 ~{}\n",
                c.wakeups,
                c.mean_reap_batch(),
                c.depth_p50(),
                c.reap_p50(),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::Direction;
    use crate::plan::Variant;

    fn key() -> RouteKey {
        RouteKey::new(Variant::Pallas, 256, Direction::Forward)
    }

    fn t(us: u64) -> Timestamp {
        Timestamp::from_nanos(us * 1_000)
    }

    #[test]
    fn amortisation_counts_batching() {
        let mut r = MetricsRegistry::new();
        r.record_launch(key(), 8, 8, 100.0, &[1.0; 8], t(0));
        r.record_launch(key(), 8, 8, 110.0, &[1.0; 8], t(1));
        r.record_launch(key(), 1, 1, 50.0, &[1.0], t(2));
        let m = r.get(&key()).unwrap();
        assert_eq!(m.requests, 17);
        assert_eq!(m.launches, 3);
        assert!((m.amortisation() - 17.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn summaries_reflect_samples() {
        let mut r = MetricsRegistry::new();
        r.record_launch(key(), 1, 1, 10.0, &[5.0], t(0));
        r.record_launch(key(), 1, 1, 30.0, &[15.0], t(1));
        let m = r.get(&key()).unwrap();
        assert!((m.exec_summary().unwrap().mean - 20.0).abs() < 1e-12);
        assert!((m.queue_summary().unwrap().mean - 10.0).abs() < 1e-12);
    }

    #[test]
    fn padded_slots_count_batch_waste() {
        let mut r = MetricsRegistry::new();
        // 5 members in a batch-8 artifact: 3 padded slots.
        r.record_launch(key(), 5, 8, 100.0, &[1.0; 5], t(0));
        // Full batch and a singleton: no padding.
        r.record_launch(key(), 8, 8, 100.0, &[1.0; 8], t(1));
        r.record_launch(key(), 1, 1, 50.0, &[1.0], t(2));
        let m = r.get(&key()).unwrap();
        assert_eq!(m.padded_slots, 3);
        assert_eq!(r.total_padded_slots(), 3);
        assert!(r.render_table().contains("padded"), "{}", r.render_table());
    }

    #[test]
    fn queue_percentiles_reported() {
        let mut r = MetricsRegistry::new();
        let samples: Vec<f64> = (0..100).map(|i| i as f64).collect();
        r.record_launch(key(), 100, 100, 10.0, &samples, t(0));
        let m = r.get(&key()).unwrap();
        let (p50, p95, p99) = m.queue_percentiles().unwrap();
        assert!((p50 - 49.5).abs() < 1e-9, "p50 {p50}");
        assert!((p95 - 94.05).abs() < 1e-9, "p95 {p95}");
        assert!((p99 - 98.01).abs() < 1e-9, "p99 {p99}");
        assert!(p50 <= p95 && p95 <= p99);
        // A heavy-tail outlier must not distort the low percentiles
        // (the exact-sample path, unlike a uniform-bin estimate).
        let mut r2 = MetricsRegistry::new();
        let mut tail = vec![10.0; 99];
        tail.push(100_000.0);
        r2.record_launch(key(), 100, 100, 10.0, &tail, t(0));
        let (p50, _, _) = r2.get(&key()).unwrap().queue_percentiles().unwrap();
        assert!((p50 - 10.0).abs() < 1e-9, "outlier distorted p50: {p50}");
        // The distribution view is still available as a histogram.
        assert_eq!(m.queue_histogram(16).unwrap().total(), 100);
    }

    #[test]
    fn sample_series_are_bounded() {
        let mut r = MetricsRegistry::new();
        let batch = vec![1.0; 512];
        for i in 0..(2 * MAX_SAMPLES_PER_KEY / batch.len() + 4) {
            r.record_launch(key(), batch.len(), batch.len(), 10.0, &batch, t(i as u64));
        }
        let m = r.get(&key()).unwrap();
        assert!(m.queue_us.len() <= MAX_SAMPLES_PER_KEY, "len {}", m.queue_us.len());
        // Counters keep the full history even though samples roll.
        assert!(m.requests as usize > MAX_SAMPLES_PER_KEY);
        assert!(m.queue_percentiles().is_some());
    }

    #[test]
    fn table_renders_all_keys() {
        let mut r = MetricsRegistry::new();
        r.record_launch(key(), 1, 1, 10.0, &[1.0], t(0));
        r.record_launch(
            RouteKey::new(Variant::Native, 512, Direction::Inverse),
            1,
            1,
            20.0,
            &[1.0],
            t(1),
        );
        let t = r.render_table();
        assert!(t.contains("pallas/n=256/fwd"));
        assert!(t.contains("native/n=512/inv"));
        assert!(t.contains("q-p99[us]"));
        assert!(t.contains("shed"));
    }

    #[test]
    fn r2c_routes_render_with_kind_marker() {
        let mut r = MetricsRegistry::new();
        r.record_launch(key(), 1, 1, 10.0, &[1.0], t(0));
        r.record_launch(
            RouteKey::r2c(Variant::Pallas, 256, Direction::Forward),
            1,
            1,
            10.0,
            &[1.0],
            t(1),
        );
        let table = r.render_table();
        // Same variant/n/direction, distinct rows: the kind marker is
        // the only difference, and the c2c label stays byte-identical
        // to the historical form.
        assert!(table.contains("pallas/n=256/fwd"), "{table}");
        assert!(table.contains("pallas/r2c/n=256/fwd"), "{table}");
        assert_eq!(r.keys().len(), 2);
    }

    #[test]
    fn empty_registry_totals_zero() {
        let r = MetricsRegistry::new();
        assert_eq!(r.total_requests(), 0);
        assert_eq!(r.total_launches(), 0);
        assert_eq!(r.total_padded_slots(), 0);
        assert_eq!(r.total_shed_requests(), 0);
        assert!(r.keys().is_empty());
    }

    #[test]
    fn planner_stats_render_as_footer() {
        let mut r = MetricsRegistry::new();
        assert!(!r.render_table().contains("plan cache"));
        r.set_planner_stats(PlannerStats {
            hits: 9,
            misses: 1,
            evictions: 0,
            cached: 1,
            capacity: 256,
        });
        let t = r.render_table();
        assert!(t.contains("plan cache: 1 cached (cap 256)"), "{t}");
        assert!(t.contains("9 hits / 1 misses (90.0% hit rate)"), "{t}");
        assert_eq!(r.planner_stats().unwrap().hits, 9);
    }

    #[test]
    fn completion_stats_render_as_footer() {
        use crate::coordinator::completion::CompletionQueue;
        let mut r = MetricsRegistry::new();
        assert!(!r.render_table().contains("completion queue"));
        let q = CompletionQueue::new(4);
        let t0 = q.open();
        q.complete(t0, Err("x".into()));
        let mut out = Vec::new();
        q.wait_any(&mut out).unwrap();
        r.set_completion_stats(q.stats());
        let table = r.render_table();
        assert!(
            table.contains(
                "completion queue: 4 slots (high water 1), 1 opened / 1 reaped, 0 in flight"
            ),
            "{table}"
        );
        assert!(table.contains("completion reaps: 1 wakeups, mean batch 1.00"), "{table}");
        assert_eq!(r.completion_stats().unwrap().opened, 1);
    }

    #[test]
    fn sliding_p99_evicts_by_time_and_needs_min_samples() {
        let window = Duration::from_millis(5);
        let mut r = MetricsRegistry::new();
        // Seven samples: below SLO_MIN_SAMPLES, no verdict yet.
        r.record_launch(key(), 7, 8, 10.0, &[2_000.0; 7], t(0));
        assert!(!r.over_slo(&key(), t(100), window, 1_000.0));
        // The eighth sample arms the window: p99 ~2000us > 1000us budget.
        r.record_launch(key(), 1, 1, 10.0, &[2_000.0], t(200));
        assert!(r.over_slo(&key(), t(300), window, 1_000.0));
        assert!(!r.over_slo(&key(), t(300), window, 3_000.0), "within a generous budget");
        // 6ms later every sample has aged out: the gate lifts.
        assert!(!r.over_slo(&key(), t(6_300), window, 1_000.0));
        // Unknown routes are never over budget.
        let other = RouteKey::new(Variant::Native, 64, Direction::Forward);
        assert!(!r.over_slo(&other, t(0), window, 1.0));
    }

    #[test]
    fn worker_section_absent_until_worker_metrics_recorded() {
        // Pinned-scheduler tables never record worker metrics, so the
        // section (and any diff vs PR 2's tables) must not appear.
        let mut r = MetricsRegistry::new();
        r.record_launch(key(), 1, 1, 10.0, &[1.0], t(0));
        assert!(!r.render_table().contains("worker"), "{}", r.render_table());
        assert_eq!(r.total_steals(), 0);
        assert_eq!(r.total_migrations(), 0);

        // One attributed launch flips the section on.
        r.record_worker_launch(0, 10.0, t(0));
        let table = r.render_table();
        assert!(table.contains("steals"), "{table}");
        assert!(table.contains("migrations"), "{table}");
    }

    #[test]
    fn worker_utilization_over_observed_span() {
        let mut r = MetricsRegistry::new();
        // Worker 0: two 100us launches starting at t=0 and t=900us, so
        // the span (first start to last completion) is exactly 1000us;
        // worker 1: idle the whole time (resized into view by the
        // steal it performed).
        r.record_worker_launch(0, 100.0, t(0));
        r.record_worker_launch(0, 100.0, t(900));
        r.record_steal(1);
        r.record_migration(1);
        let w = r.workers();
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].launches, 2);
        assert!((w[0].busy_us - 200.0).abs() < 1e-12);
        assert_eq!(w[1].steals, 1);
        assert_eq!(w[1].migrations, 1);
        assert_eq!(r.total_steals(), 1);
        assert_eq!(r.total_migrations(), 1);
        let table = r.render_table();
        // busy 200us over the 1000us span = 20% utilization.
        assert!(table.contains("20.0"), "{table}");
        assert!(table.contains("w0"), "{table}");
        assert!(table.contains("w1"), "{table}");
    }

    #[test]
    fn saturated_worker_utilization_caps_at_hundred_percent() {
        // Back-to-back 50us launches: busy time (100us) equals the
        // span exactly, so utilization is 100% — a span ending at the
        // last *start* (50us) would have reported 200%.
        let mut r = MetricsRegistry::new();
        r.record_worker_launch(0, 50.0, t(0));
        r.record_worker_launch(0, 50.0, t(50));
        let table = r.render_table();
        assert!(table.contains("100.0"), "{table}");
        assert!(!table.contains("200.0"), "{table}");
    }

    #[test]
    fn shed_requests_are_counted_and_rendered() {
        let mut r = MetricsRegistry::new();
        r.record_shed(key());
        r.record_shed(key());
        assert_eq!(r.get(&key()).unwrap().shed_requests, 2);
        assert_eq!(r.total_shed_requests(), 2);
        let table = r.render_table();
        assert!(table.contains("shed"), "{table}");
    }
}
