//! Serving metrics: per-route latency decomposition.
//!
//! Mirrors the paper's measurement protocol — every request records
//! queueing delay, launch (dispatch) estimate and execution wall time,
//! so the serving path can regenerate the §6.1 tables without a separate
//! instrumentation harness.

use std::collections::HashMap;

use super::RouteKey;
use crate::fft::PlannerStats;
use crate::stats::Summary;

/// Accumulated samples for one routing key.
#[derive(Clone, Debug, Default)]
pub struct KeyMetrics {
    pub requests: u64,
    pub launches: u64,
    pub batched_requests: u64,
    pub queue_us: Vec<f64>,
    pub exec_us: Vec<f64>,
}

impl KeyMetrics {
    /// Requests amortised per launch (the batcher's win).
    pub fn amortisation(&self) -> f64 {
        if self.launches == 0 {
            0.0
        } else {
            self.requests as f64 / self.launches as f64
        }
    }

    pub fn exec_summary(&self) -> Option<Summary> {
        if self.exec_us.is_empty() {
            None
        } else {
            Some(Summary::from_samples(&self.exec_us))
        }
    }

    pub fn queue_summary(&self) -> Option<Summary> {
        if self.queue_us.is_empty() {
            None
        } else {
            Some(Summary::from_samples(&self.queue_us))
        }
    }
}

/// Registry over all keys.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    by_key: HashMap<RouteKey, KeyMetrics>,
    /// Latest snapshot of the plan-cache counters (see
    /// `fft::FftPlanner`), rendered as a table footer.
    planner: Option<PlannerStats>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Attach the latest planner cache counters.
    pub fn set_planner_stats(&mut self, stats: PlannerStats) {
        self.planner = Some(stats);
    }

    pub fn planner_stats(&self) -> Option<PlannerStats> {
        self.planner
    }

    /// Record one launch carrying `members` requests.
    pub fn record_launch(&mut self, key: RouteKey, members: usize, exec_us: f64, queue_us: &[f64]) {
        let m = self.by_key.entry(key).or_default();
        m.launches += 1;
        m.requests += members as u64;
        if members > 1 {
            m.batched_requests += members as u64;
        }
        m.exec_us.push(exec_us);
        m.queue_us.extend_from_slice(queue_us);
    }

    pub fn get(&self, key: &RouteKey) -> Option<&KeyMetrics> {
        self.by_key.get(key)
    }

    pub fn keys(&self) -> Vec<RouteKey> {
        let mut v: Vec<RouteKey> = self.by_key.keys().copied().collect();
        v.sort_by_key(|k| (k.n, k.variant.name(), k.direction.name()));
        v
    }

    pub fn total_requests(&self) -> u64 {
        self.by_key.values().map(|m| m.requests).sum()
    }

    pub fn total_launches(&self) -> u64 {
        self.by_key.values().map(|m| m.launches).sum()
    }

    /// Render an aligned text table (one row per key).
    pub fn render_table(&self) -> String {
        let mut out = String::from(
            "route                          reqs  launches  reqs/launch  exec-mean[us]  exec-min[us]\n",
        );
        for key in self.keys() {
            let m = &self.by_key[&key];
            let s = m.exec_summary();
            out.push_str(&format!(
                "{:<28} {:>6} {:>9} {:>12.2} {:>14.1} {:>13.1}\n",
                format!("{}/n={}/{}", key.variant.name(), key.n, key.direction.name()),
                m.requests,
                m.launches,
                m.amortisation(),
                s.map_or(0.0, |s| s.mean),
                s.map_or(0.0, |s| s.min),
            ));
        }
        if let Some(p) = self.planner {
            out.push_str(&format!(
                "plan cache: {} cached (cap {}), {} hits / {} misses ({:.1}% hit rate), {} evictions\n",
                p.cached,
                p.capacity,
                p.hits,
                p.misses,
                100.0 * p.hit_rate(),
                p.evictions,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::Direction;
    use crate::plan::Variant;

    fn key() -> RouteKey {
        RouteKey::new(Variant::Pallas, 256, Direction::Forward)
    }

    #[test]
    fn amortisation_counts_batching() {
        let mut r = MetricsRegistry::new();
        r.record_launch(key(), 8, 100.0, &[1.0; 8]);
        r.record_launch(key(), 8, 110.0, &[1.0; 8]);
        r.record_launch(key(), 1, 50.0, &[1.0]);
        let m = r.get(&key()).unwrap();
        assert_eq!(m.requests, 17);
        assert_eq!(m.launches, 3);
        assert!((m.amortisation() - 17.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn summaries_reflect_samples() {
        let mut r = MetricsRegistry::new();
        r.record_launch(key(), 1, 10.0, &[5.0]);
        r.record_launch(key(), 1, 30.0, &[15.0]);
        let m = r.get(&key()).unwrap();
        assert!((m.exec_summary().unwrap().mean - 20.0).abs() < 1e-12);
        assert!((m.queue_summary().unwrap().mean - 10.0).abs() < 1e-12);
    }

    #[test]
    fn table_renders_all_keys() {
        let mut r = MetricsRegistry::new();
        r.record_launch(key(), 1, 10.0, &[1.0]);
        r.record_launch(RouteKey::new(Variant::Native, 512, Direction::Inverse), 1, 20.0, &[1.0]);
        let t = r.render_table();
        assert!(t.contains("pallas/n=256/fwd"));
        assert!(t.contains("native/n=512/inv"));
    }

    #[test]
    fn empty_registry_totals_zero() {
        let r = MetricsRegistry::new();
        assert_eq!(r.total_requests(), 0);
        assert_eq!(r.total_launches(), 0);
        assert!(r.keys().is_empty());
    }

    #[test]
    fn planner_stats_render_as_footer() {
        let mut r = MetricsRegistry::new();
        assert!(!r.render_table().contains("plan cache"));
        r.set_planner_stats(PlannerStats {
            hits: 9,
            misses: 1,
            evictions: 0,
            cached: 1,
            capacity: 256,
        });
        let t = r.render_table();
        assert!(t.contains("plan cache: 1 cached (cap 256)"), "{t}");
        assert!(t.contains("9 hits / 1 misses (90.0% hit rate)"), "{t}");
        assert_eq!(r.planner_stats().unwrap().hits, 9);
    }
}
