//! Injectable time for the serving path.
//!
//! Every time read inside `coordinator/` goes through a [`Clock`], so
//! the whole serving stack — enqueue stamps, coalescing-window
//! deadlines, launch timing, SLO sliding windows — runs identically on
//! wall time ([`WallClock`]) and on manually-advanced simulated time
//! ([`SimClock`]).  That is what makes the deterministic simulation
//! suite (`tests/sim_coordinator.rs`) possible: time-dependent policy
//! behaviour (adaptive batching, admission control) is asserted on
//! scripted timelines with no sleeps and bit-reproducible output.
//!
//! The rule this module enforces by existing: **no raw `Instant::now()`
//! inside `coordinator/`** (DESIGN.md §11).  `Instant` itself cannot be
//! fabricated for a simulated timeline, so the serving path trades it
//! for [`Timestamp`] — nanoseconds since the clock's epoch.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A point on a [`Clock`]'s timeline: nanoseconds since its epoch.
///
/// Ordered, copyable and arithmetic-friendly — unlike `Instant`, a
/// `Timestamp` can be minted at any value by a simulated clock.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Timestamp(u64);

impl Timestamp {
    pub const ZERO: Timestamp = Timestamp(0);

    pub fn from_nanos(nanos: u64) -> Timestamp {
        Timestamp(nanos)
    }

    pub fn as_nanos(self) -> u64 {
        self.0
    }

    /// Elapsed time since `earlier` (zero if `earlier` is later).
    pub fn saturating_since(self, earlier: Timestamp) -> Duration {
        Duration::from_nanos(self.0.saturating_sub(earlier.0))
    }

    /// Elapsed microseconds since `earlier` (zero if `earlier` is later).
    pub fn micros_since(self, earlier: Timestamp) -> f64 {
        self.0.saturating_sub(earlier.0) as f64 / 1e3
    }
}

impl std::ops::Add<Duration> for Timestamp {
    type Output = Timestamp;
    fn add(self, d: Duration) -> Timestamp {
        Timestamp(self.0.saturating_add(d.as_nanos().min(u64::MAX as u128) as u64))
    }
}

/// The serving path's time source.
///
/// Implementations must be thread-safe: the leader, the worker pool and
/// every client handle share one clock behind an `Arc`.
pub trait Clock: Send + Sync + fmt::Debug {
    /// Current time on this clock's timeline.
    fn now(&self) -> Timestamp;

    /// Block (or advance, for a simulated clock) until `deadline`.
    ///
    /// [`WallClock`] puts the calling thread to sleep; [`SimClock`]
    /// advances its own timeline instead, so a single-threaded driver
    /// paces an arrival script without any real waiting.
    fn sleep_until(&self, deadline: Timestamp);
}

/// Real time: `now` is the wall-clock elapsed since construction.
#[derive(Debug)]
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    pub fn new() -> WallClock {
        WallClock { epoch: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> Timestamp {
        Timestamp(self.epoch.elapsed().as_nanos().min(u64::MAX as u128) as u64)
    }

    fn sleep_until(&self, deadline: Timestamp) {
        let now = self.now();
        if deadline > now {
            std::thread::sleep(deadline.saturating_since(now));
        }
    }
}

/// Manually-advanced simulated time.
///
/// `now` only moves when a driver calls [`SimClock::advance`] /
/// [`SimClock::set`] (or sleeps, which fast-forwards the timeline), so
/// a scripted workload observes exactly the delays the script wrote —
/// no scheduler jitter, no flaky wall-clock waits.  The counter is a
/// single atomic, safe to share across threads, though deterministic
/// assertions belong in single-threaded drivers (`SimCoordinator`).
#[derive(Debug, Default)]
pub struct SimClock {
    nanos: AtomicU64,
}

impl SimClock {
    pub fn new() -> Arc<SimClock> {
        Arc::new(SimClock { nanos: AtomicU64::new(0) })
    }

    /// Move the timeline forward by `d`.
    pub fn advance(&self, d: Duration) {
        self.nanos.fetch_add(d.as_nanos().min(u64::MAX as u128) as u64, Ordering::SeqCst);
    }

    /// Jump the timeline to `t` (never backwards).
    pub fn set(&self, t: Timestamp) {
        self.nanos.fetch_max(t.as_nanos(), Ordering::SeqCst);
    }
}

impl Clock for SimClock {
    fn now(&self) -> Timestamp {
        Timestamp(self.nanos.load(Ordering::SeqCst))
    }

    /// A simulated sleeper owns the progression of time: sleeping to a
    /// deadline fast-forwards the timeline there (never backwards).
    fn sleep_until(&self, deadline: Timestamp) {
        self.set(deadline);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_is_monotone() {
        let c = WallClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn sim_clock_advances_only_on_demand() {
        let c = SimClock::new();
        assert_eq!(c.now(), Timestamp::ZERO);
        c.advance(Duration::from_micros(250));
        assert_eq!(c.now().as_nanos(), 250_000);
        // now() does not move on its own.
        assert_eq!(c.now().as_nanos(), 250_000);
    }

    #[test]
    fn sim_sleep_fast_forwards_never_rewinds() {
        let c = SimClock::new();
        c.sleep_until(Timestamp::from_nanos(5_000));
        assert_eq!(c.now().as_nanos(), 5_000);
        c.sleep_until(Timestamp::from_nanos(1_000)); // already past: no-op
        assert_eq!(c.now().as_nanos(), 5_000);
        c.set(Timestamp::from_nanos(4_000));
        assert_eq!(c.now().as_nanos(), 5_000, "set must never rewind");
    }

    #[test]
    fn timestamp_arithmetic() {
        let t = Timestamp::from_nanos(3_000) + Duration::from_nanos(500);
        assert_eq!(t.as_nanos(), 3_500);
        assert_eq!(t.micros_since(Timestamp::from_nanos(1_500)), 2.0);
        assert_eq!(Timestamp::ZERO.micros_since(t), 0.0, "saturates at zero");
        assert_eq!(t.saturating_since(Timestamp::from_nanos(3_000)), Duration::from_nanos(500));
    }
}
