//! Deterministic, single-threaded coordinator simulation.
//!
//! [`SimCoordinator`] drives the *same* queueing, batching, admission
//! and execution code as the threaded service — [`LeaderCore`],
//! `admission_check` and `run_batch` are shared, not reimplemented —
//! but synchronously, on a manually-advanced [`SimClock`]:
//!
//! * `submit` is the threaded handle's admission + enqueue path;
//! * `step` closes the coalescing window (the leader's drain) and
//!   executes every resulting work item inline, replying on the same
//!   per-request channels clients of the threaded service hold.
//!
//! Because nothing runs concurrently and every time read comes from the
//! simulated clock, a scripted workload is bit-reproducible: two runs
//! of the same script produce identical responses and an identical
//! metrics table (`tests/sim_coordinator.rs` asserts exactly that).
//! This is the harness the ROADMAP's "simulation-first policy
//! development" note refers to — adaptive batching and SLO shedding
//! were grown against these scripts before ever running on wall time.
//!
//! One deliberate difference from the threaded `metrics_table`: the
//! sim table omits the process-global planner-cache footer, whose
//! counters depend on whatever else the process has planned and would
//! break run-to-run reproducibility.
//!
//! **Scheduled worker model.**  By default `step` executes every
//! drained launch inline (an infinite-service-rate pool).  Built with
//! [`SimCoordinator::with_worker_model`], `step` instead drives the
//! *real* dispatch scheduler ([`SchedulerCore`], shared with the
//! threaded pools): drained launches are placed per `cfg.scheduler`
//! (pinned round-robin or load-aware), each simulated worker then
//! completes a bounded number of launches per window — in worker-index
//! order, so the whole thing is deterministic — and idle workers steal
//! whole-route ownership exactly as the threaded `StealingPool` does.
//! Backlog carries across windows, which is what lets a script measure
//! *simulated windows to drain* under hot-route skew
//! (`tests/scheduler_sim.rs`).

use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::clock::{Clock, SimClock, Timestamp};
use super::completion::{CompletionQueue, ReplySink, Ticket};
use super::metrics::MetricsRegistry;
use super::scheduler::SchedulerCore;
use super::service::{
    admission_check, CoordinatorConfig, FftRequest, FftResponse, LeaderCore, StreamSpec,
    R2C_DISABLED_ERROR,
};
use super::worker::run_batch;
use super::RouteKey;
use super::RouteKind;
use super::SchedulerKind;
use crate::fft::Scratch;
use crate::runtime::FftLibrary;
use crate::signal::window;

/// Finite-service-rate worker model around the shared scheduler core.
struct SimWorkers {
    core: SchedulerCore,
    /// Launches each simulated worker completes per window.
    per_window: usize,
}

/// The synchronous simulation driver around the shared serving core.
pub struct SimCoordinator {
    clock: Arc<SimClock>,
    lib: FftLibrary,
    metrics: Arc<Mutex<MetricsRegistry>>,
    core: LeaderCore,
    slo_p99_us: Option<f64>,
    slo_window: Duration,
    /// `None`: the default inline model (every drained launch executes
    /// immediately).  `Some`: the scheduled worker model.
    workers: Option<SimWorkers>,
    /// The simulator executes inline on the driving thread, so it owns
    /// one scratch arena (like a coordinator worker owns its own).
    scratch: Scratch,
    /// Honour `cfg.legacy_aos_exec` like the threaded pools do (the
    /// two execution paths are bit-identical, so simulated payloads
    /// and metrics are unaffected either way).
    legacy_aos: bool,
    /// Mirror of the threaded handle's `coordinator.r2c_routes` gate.
    r2c_routes: bool,
    /// The simulated twin of the threaded handle's completion queue:
    /// the identical slab (same type, same slot/sequence semantics)
    /// fed synchronously by `step` — fan-in policy develops here first
    /// (DESIGN.md §18).
    completions: Arc<CompletionQueue>,
}

impl SimCoordinator {
    /// Build a simulated coordinator over `cfg`'s artifact directory
    /// and batching/SLO policy, on the given simulated clock
    /// (`cfg.clock` and `cfg.workers` are irrelevant here: execution is
    /// inline and time is `clock`).
    pub fn new(cfg: &CoordinatorConfig, clock: Arc<SimClock>) -> Result<SimCoordinator> {
        let lib = FftLibrary::open(&cfg.artifacts_dir)?;
        Ok(SimCoordinator {
            clock,
            lib,
            metrics: Arc::new(Mutex::new(MetricsRegistry::new())),
            core: LeaderCore::new(cfg.batcher, cfg.coalesce_window),
            slo_p99_us: cfg.slo_p99_us,
            slo_window: cfg.slo_window,
            workers: None,
            scratch: Scratch::new(),
            legacy_aos: cfg.legacy_aos_exec,
            r2c_routes: cfg.r2c_routes,
            completions: Arc::new(CompletionQueue::new(cfg.completion_slots)),
        })
    }

    /// Build a simulated coordinator whose `step` drives the *real*
    /// dispatch scheduler (`cfg.workers` simulated workers under
    /// `cfg.scheduler`) at a finite service rate of
    /// `launches_per_window` launches per worker per window, instead of
    /// executing every drained launch inline.  Placement, stealing and
    /// ownership migration run deterministically on the injected
    /// `SimClock` timeline; backlog carries across windows.
    ///
    /// The sim pool is unbounded: the threaded pools' queue-capacity
    /// backpressure is exercised by the integration tests, while the
    /// sim measures scheduling policy.
    pub fn with_worker_model(
        cfg: &CoordinatorConfig,
        clock: Arc<SimClock>,
        launches_per_window: usize,
    ) -> Result<SimCoordinator> {
        let mut sim = SimCoordinator::new(cfg, clock)?;
        let workers = cfg.workers.max(1);
        if cfg.scheduler == SchedulerKind::Stealing {
            // Mirror the threaded pool: every worker gets a metrics row
            // from the start (idle rows are part of the balance story).
            sim.metrics.lock().unwrap().set_worker_count(workers);
        }
        sim.workers = Some(SimWorkers {
            core: SchedulerCore::new(cfg.scheduler, workers, usize::MAX),
            per_window: launches_per_window.max(1),
        });
        Ok(sim)
    }

    /// The simulated clock (shared with the script driving this).
    pub fn clock(&self) -> Arc<SimClock> {
        self.clock.clone()
    }

    pub fn now(&self) -> Timestamp {
        self.clock.now()
    }

    /// Move simulated time forward.
    pub fn advance(&self, d: Duration) {
        self.clock.advance(d);
    }

    /// The threaded handle's submit logic — the shared validation and
    /// SLO admission gate, then an enqueue stamped with the simulated
    /// arrival time.  (The threaded handle's shutdown flag and bounded
    /// queue have no synchronous equivalent and are exercised by the
    /// integration tests instead.)
    pub fn submit(
        &mut self,
        req: FftRequest,
    ) -> Result<mpsc::Receiver<Result<FftResponse, String>>> {
        req.validate().map_err(|e| anyhow!(e))?;
        if req.kind == RouteKind::R2c && !self.r2c_routes {
            return Err(anyhow!(R2C_DISABLED_ERROR));
        }
        let now = self.clock.now();
        admission_check(&self.metrics, req.key(), now, self.slo_p99_us, self.slo_window)
            .map_err(|e| anyhow!(e))?;
        let (tx, rx) = mpsc::channel(); // lint:allow(no-adhoc-reply-channel): the blocking compat wrapper
        self.core.enqueue(req, now, tx.into());
        Ok(rx)
    }

    /// The threaded handle's [`submit_nowait`] on simulated time:
    /// admission, then a [`Ticket`] against the sim's completion queue
    /// instead of a per-request channel.  An SLO shed returns a ticket
    /// born completed with the shed error.  `step` (plus enough
    /// simulated time for the batcher's fill gate) resolves tickets;
    /// harvest them with [`SimCoordinator::completions`].
    ///
    /// [`submit_nowait`]: super::service::CoordinatorHandle::submit_nowait
    pub fn submit_nowait(&mut self, req: FftRequest) -> Result<Ticket> {
        req.validate().map_err(|e| anyhow!(e))?;
        if req.kind == RouteKind::R2c && !self.r2c_routes {
            return Err(anyhow!(R2C_DISABLED_ERROR));
        }
        let now = self.clock.now();
        if let Err(msg) =
            admission_check(&self.metrics, req.key(), now, self.slo_p99_us, self.slo_window)
        {
            return Ok(self.completions.preloaded_err(msg));
        }
        let ticket = self.completions.open();
        self.core.enqueue(req, now, ReplySink::queue(self.completions.clone(), ticket));
        Ok(ticket)
    }

    /// The completion surface `submit_nowait` and `submit_stream`
    /// tickets resolve against.
    pub fn completions(&self) -> &Arc<CompletionQueue> {
        &self.completions
    }

    /// The threaded handle's [`submit_stream`] on simulated time: slice
    /// `samples` into hop-advanced frames, apply the window function,
    /// and submit each frame as a packed-real r2c request — one
    /// [`Ticket`] per frame appended to `out`, in stream order.  An
    /// SLO-shed frame yields a ticket born completed with the shed
    /// error (the stream keeps flowing — a dropped spectrogram column,
    /// not a dead stream); any other submission error aborts, leaving
    /// already-appended tickets valid and reapable.
    ///
    /// Like the threaded path, the coefficient and frame buffers are
    /// `Scratch` leases and the packed request planes come from the
    /// completion queue's spare pool — zero steady-state allocations
    /// once the pools are warm (pinned in `tests/completion_sim.rs`).
    ///
    /// [`submit_stream`]: super::service::CoordinatorHandle::submit_stream
    pub fn submit_stream(
        &mut self,
        spec: &StreamSpec,
        samples: &[f32],
        out: &mut Vec<Ticket>,
    ) -> Result<usize> {
        spec.validate().map_err(|e| anyhow!(e))?;
        if !self.r2c_routes {
            return Err(anyhow!(R2C_DISABLED_ERROR));
        }
        // The thread-local arena, not `self.scratch`: the submit path
        // needs `&mut self` per frame while the leases live.
        Scratch::with_local(|scratch| {
            let mut coeffs = scratch.lease_f32_dirty(spec.frame);
            spec.window.write_coefficients(&mut coeffs);
            let mut frame = scratch.lease_f32_dirty(spec.frame);
            let mut frames = 0usize;
            let mut start = 0usize;
            while start + spec.frame <= samples.len() {
                frame.copy_from_slice(&samples[start..start + spec.frame]);
                window::apply(&mut frame, &coeffs);
                let (mut re, mut im) = self.completions.lease_planes(spec.frame / 2);
                crate::fft::pack_real(&frame, &mut re, &mut im);
                let req = FftRequest::new_r2c(spec.variant, crate::fft::Direction::Forward, re, im);
                out.push(self.submit_nowait(req)?);
                frames += 1;
                start += spec.hop;
            }
            Ok(frames)
        })
    }

    /// Close the coalescing window: drain the batcher into launches and
    /// run one window of the execution model at the current simulated
    /// instant.
    ///
    /// Inline model (default): every launch executes immediately;
    /// nothing is left pending.  Scheduled worker model
    /// ([`SimCoordinator::with_worker_model`]): launches are *placed*
    /// by the real scheduler, each worker then completes up to its
    /// per-window budget (idle workers stealing first), and whatever
    /// remains stays queued for the next window — see [`backlog`].
    ///
    /// [`backlog`]: SimCoordinator::backlog
    pub fn step(&mut self) {
        let clock: &dyn Clock = self.clock.as_ref();
        let items = self.core.drain();
        match &mut self.workers {
            None => {
                for item in items {
                    let scratch = &self.scratch;
                    let legacy = self.legacy_aos;
                    run_batch(&self.lib, &self.metrics, clock, item, None, scratch, legacy);
                }
            }
            Some(w) => {
                let stealing = w.core.kind() == SchedulerKind::Stealing;
                for item in items {
                    // The sim pool is unbounded, so placement never
                    // bounces; worker metrics (like the threaded path)
                    // are recorded only under the stealing scheduler.
                    let Ok(p) = w.core.place(item) else { unreachable!("sim pool is unbounded") };
                    if stealing && p.migrated {
                        self.metrics.lock().unwrap().record_migration(p.worker);
                    }
                }
                for _ in 0..w.per_window {
                    for worker in 0..w.core.workers() {
                        let si = match w.core.pop(worker) {
                            Some(si) => si,
                            None => {
                                let Some(ev) = w.core.steal(worker) else { continue };
                                self.metrics.lock().unwrap().record_steal(ev.thief);
                                match w.core.pop(worker) {
                                    Some(si) => si,
                                    None => continue,
                                }
                            }
                        };
                        let key = si.item.key;
                        run_batch(
                            &self.lib,
                            &self.metrics,
                            clock,
                            si.item,
                            stealing.then_some(worker),
                            &self.scratch,
                            self.legacy_aos,
                        );
                        w.core.complete(worker, key);
                    }
                }
            }
        }
    }

    /// `advance(window)` + `step()`: one scripted serving window.
    pub fn run_window(&mut self, window: Duration) {
        self.advance(window);
        self.step();
    }

    /// The `min_fill` the adaptive policy would apply to `key` in the
    /// next window (for convergence assertions).
    pub fn effective_min_fill(&self, key: &RouteKey) -> usize {
        self.core.batcher().effective_min_fill(key, self.core.batcher_cfg())
    }

    /// Rendered per-route metrics table (no planner footer — see the
    /// module docs on reproducibility).  The completion-queue footer
    /// appears only once a ticket has been opened, so blocking-only
    /// scripts render byte-identically to pre-PR-10 runs.
    pub fn metrics_table(&self) -> String {
        let stats = self.completions.stats();
        let mut m = self.metrics.lock().unwrap();
        if stats.opened > 0 {
            m.set_completion_stats(stats);
        }
        m.render_table()
    }

    /// Run a closure over the live metrics registry (for assertions).
    pub fn with_metrics<R>(&self, f: impl FnOnce(&MetricsRegistry) -> R) -> R {
        f(&self.metrics.lock().unwrap())
    }

    pub fn total_padded_slots(&self) -> u64 {
        self.with_metrics(|m| m.total_padded_slots())
    }

    pub fn total_launches(&self) -> u64 {
        self.with_metrics(|m| m.total_launches())
    }

    pub fn total_requests(&self) -> u64 {
        self.with_metrics(|m| m.total_requests())
    }

    pub fn total_shed_requests(&self) -> u64 {
        self.with_metrics(|m| m.total_shed_requests())
    }

    /// Launches still queued in the scheduled worker model (always 0
    /// under the inline model, which leaves nothing pending).  A script
    /// measures "windows to drain" by stepping until this hits zero.
    pub fn backlog(&self) -> usize {
        self.workers.as_ref().map_or(0, |w| w.core.queued_total())
    }

    /// Whole-route steals performed by the scheduled worker model.
    pub fn total_steals(&self) -> u64 {
        self.workers.as_ref().map_or(0, |w| w.core.steals())
    }

    /// Placement-time ownership migrations in the scheduled worker
    /// model.
    pub fn total_migrations(&self) -> u64 {
        self.workers.as_ref().map_or(0, |w| w.core.migrations())
    }
}
