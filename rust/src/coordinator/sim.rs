//! Deterministic, single-threaded coordinator simulation.
//!
//! [`SimCoordinator`] drives the *same* queueing, batching, admission
//! and execution code as the threaded service — [`LeaderCore`],
//! `admission_check` and `run_batch` are shared, not reimplemented —
//! but synchronously, on a manually-advanced [`SimClock`]:
//!
//! * `submit` is the threaded handle's admission + enqueue path;
//! * `step` closes the coalescing window (the leader's drain) and
//!   executes every resulting work item inline, replying on the same
//!   per-request channels clients of the threaded service hold.
//!
//! Because nothing runs concurrently and every time read comes from the
//! simulated clock, a scripted workload is bit-reproducible: two runs
//! of the same script produce identical responses and an identical
//! metrics table (`tests/sim_coordinator.rs` asserts exactly that).
//! This is the harness the ROADMAP's "simulation-first policy
//! development" note refers to — adaptive batching and SLO shedding
//! were grown against these scripts before ever running on wall time.
//!
//! One deliberate difference from the threaded `metrics_table`: the
//! sim table omits the process-global planner-cache footer, whose
//! counters depend on whatever else the process has planned and would
//! break run-to-run reproducibility.

use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::clock::{Clock, SimClock, Timestamp};
use super::metrics::MetricsRegistry;
use super::service::{admission_check, CoordinatorConfig, FftRequest, FftResponse, LeaderCore};
use super::worker::run_batch;
use super::RouteKey;
use crate::runtime::FftLibrary;

/// The synchronous simulation driver around the shared serving core.
pub struct SimCoordinator {
    clock: Arc<SimClock>,
    lib: FftLibrary,
    metrics: Arc<Mutex<MetricsRegistry>>,
    core: LeaderCore,
    slo_p99_us: Option<f64>,
    slo_window: Duration,
}

impl SimCoordinator {
    /// Build a simulated coordinator over `cfg`'s artifact directory
    /// and batching/SLO policy, on the given simulated clock
    /// (`cfg.clock` and `cfg.workers` are irrelevant here: execution is
    /// inline and time is `clock`).
    pub fn new(cfg: &CoordinatorConfig, clock: Arc<SimClock>) -> Result<SimCoordinator> {
        let lib = FftLibrary::open(&cfg.artifacts_dir)?;
        Ok(SimCoordinator {
            clock,
            lib,
            metrics: Arc::new(Mutex::new(MetricsRegistry::new())),
            core: LeaderCore::new(cfg.batcher, cfg.coalesce_window),
            slo_p99_us: cfg.slo_p99_us,
            slo_window: cfg.slo_window,
        })
    }

    /// The simulated clock (shared with the script driving this).
    pub fn clock(&self) -> Arc<SimClock> {
        self.clock.clone()
    }

    pub fn now(&self) -> Timestamp {
        self.clock.now()
    }

    /// Move simulated time forward.
    pub fn advance(&self, d: Duration) {
        self.clock.advance(d);
    }

    /// The threaded handle's submit logic — the shared validation and
    /// SLO admission gate, then an enqueue stamped with the simulated
    /// arrival time.  (The threaded handle's shutdown flag and bounded
    /// queue have no synchronous equivalent and are exercised by the
    /// integration tests instead.)
    pub fn submit(
        &mut self,
        req: FftRequest,
    ) -> Result<mpsc::Receiver<Result<FftResponse, String>>> {
        req.validate().map_err(|e| anyhow!(e))?;
        let now = self.clock.now();
        admission_check(&self.metrics, req.key(), now, self.slo_p99_us, self.slo_window)
            .map_err(|e| anyhow!(e))?;
        let (tx, rx) = mpsc::channel();
        self.core.enqueue(req, now, tx);
        Ok(rx)
    }

    /// Close the coalescing window: drain the batcher and execute every
    /// resulting launch inline at the current simulated instant.
    /// Equivalent to the leader finishing one window; leaves nothing
    /// pending.
    pub fn step(&mut self) {
        for item in self.core.drain() {
            let clock: &dyn Clock = self.clock.as_ref();
            run_batch(&self.lib, &self.metrics, clock, item);
        }
    }

    /// `advance(window)` + `step()`: one scripted serving window.
    pub fn run_window(&mut self, window: Duration) {
        self.advance(window);
        self.step();
    }

    /// The `min_fill` the adaptive policy would apply to `key` in the
    /// next window (for convergence assertions).
    pub fn effective_min_fill(&self, key: &RouteKey) -> usize {
        self.core.batcher().effective_min_fill(key, self.core.batcher_cfg())
    }

    /// Rendered per-route metrics table (no planner footer — see the
    /// module docs on reproducibility).
    pub fn metrics_table(&self) -> String {
        self.metrics.lock().unwrap().render_table()
    }

    /// Run a closure over the live metrics registry (for assertions).
    pub fn with_metrics<R>(&self, f: impl FnOnce(&MetricsRegistry) -> R) -> R {
        f(&self.metrics.lock().unwrap())
    }

    pub fn total_padded_slots(&self) -> u64 {
        self.with_metrics(|m| m.total_padded_slots())
    }

    pub fn total_launches(&self) -> u64 {
        self.with_metrics(|m| m.total_launches())
    }

    pub fn total_requests(&self) -> u64 {
        self.with_metrics(|m| m.total_requests())
    }

    pub fn total_shed_requests(&self) -> u64 {
        self.with_metrics(|m| m.total_shed_requests())
    }
}
