//! Deterministic PRNG substrate (xorshift64* + Box-Muller).
//!
//! The device simulator and the workload generators must be reproducible
//! and dependency-free, so we carry our own small generator instead of
//! the `rand` crate (unavailable offline, and far more than we need).

/// xorshift64* — fast, passes BigCrush on the high bits, one u64 of state.
#[derive(Clone, Debug)]
pub struct XorShift64 {
    state: u64,
    /// Cached second Box-Muller variate.
    spare: Option<f64>,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point; splitmix the seed once to
        // decorrelate small consecutive seeds.
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        XorShift64 { state: (z ^ (z >> 31)) | 1, spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_f64() * n as f64) as usize % n.max(1)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        // u in (0,1] to keep ln() finite.
        let u = 1.0 - self.next_f64();
        let v = self.next_f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = XorShift64::new(3);
        for _ in 0..10000 {
            let v = r.uniform(5.0, 10.0);
            assert!((5.0..10.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_near_center() {
        let mut r = XorShift64::new(4);
        let mean: f64 = (0..50000).map(|_| r.next_f64()).sum::<f64>() / 50000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = XorShift64::new(5);
        let xs: Vec<f64> = (0..50000).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = XorShift64::new(6);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn chance_probability() {
        let mut r = XorShift64::new(7);
        let hits = (0..100000).filter(|_| r.chance(0.25)).count();
        let frac = hits as f64 / 100000.0;
        assert!((frac - 0.25).abs() < 0.01, "frac {frac}");
    }
}
