//! Window functions for spectral analysis.
//!
//! Real FFT workloads (the condition-monitoring applications the paper's
//! intro motivates) almost always window their frames before the
//! transform; this module provides the standard family plus the
//! coherent/incoherent gain corrections the PSD estimator needs.

/// Supported window shapes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Window {
    /// No tapering (all-ones).
    Rectangular,
    /// Hann: `0.5 (1 - cos(2 pi n / (N-1)))` — the default for PSDs.
    Hann,
    /// Hamming: `0.54 - 0.46 cos(2 pi n / (N-1))`.
    Hamming,
    /// Blackman (3-term, a0 = 0.42).
    Blackman,
}

impl Window {
    /// Sample the window at length `n`.
    pub fn coefficients(self, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n];
        self.write_coefficients(&mut out);
        out
    }

    /// Sample the window into a caller-provided buffer (its length is
    /// the window length) — the allocation-free form the streaming
    /// submit path leases its coefficient buffer through.
    pub fn write_coefficients(self, out: &mut [f32]) {
        let n = out.len();
        assert!(n >= 2, "window length must be at least 2");
        let d = (n - 1) as f32;
        for (i, slot) in out.iter_mut().enumerate() {
            let x = 2.0 * std::f32::consts::PI * i as f32 / d;
            *slot = match self {
                Window::Rectangular => 1.0,
                Window::Hann => 0.5 * (1.0 - x.cos()),
                Window::Hamming => 0.54 - 0.46 * x.cos(),
                Window::Blackman => 0.42 - 0.5 * x.cos() + 0.08 * (2.0 * x).cos(),
            };
        }
    }

    /// Coherent gain: mean of the coefficients (amplitude correction).
    pub fn coherent_gain(self, n: usize) -> f64 {
        let c = self.coefficients(n);
        c.iter().map(|&v| v as f64).sum::<f64>() / n as f64
    }

    /// Incoherent (power) gain: mean of squared coefficients — the
    /// normalisation used by Welch's method.
    pub fn power_gain(self, n: usize) -> f64 {
        let c = self.coefficients(n);
        c.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / n as f64
    }

    pub fn name(self) -> &'static str {
        match self {
            Window::Rectangular => "rectangular",
            Window::Hann => "hann",
            Window::Hamming => "hamming",
            Window::Blackman => "blackman",
        }
    }

    /// Inverse of [`Window::name`], for config files
    /// (`harness.stream_window`).
    pub fn parse(s: &str) -> Option<Window> {
        match s {
            "rectangular" => Some(Window::Rectangular),
            "hann" => Some(Window::Hann),
            "hamming" => Some(Window::Hamming),
            "blackman" => Some(Window::Blackman),
            _ => None,
        }
    }
}

/// Multiply a frame by a window in place.
pub fn apply(frame: &mut [f32], coeffs: &[f32]) {
    assert_eq!(frame.len(), coeffs.len());
    for (x, &w) in frame.iter_mut().zip(coeffs) {
        *x *= w;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{c32, Complex32, Direction, FftPlan, FftPlanner};

    #[test]
    fn rectangular_is_ones() {
        assert!(Window::Rectangular.coefficients(16).iter().all(|&v| v == 1.0));
    }

    #[test]
    fn hann_endpoints_zero_center_one() {
        let c = Window::Hann.coefficients(65);
        assert!(c[0].abs() < 1e-7);
        assert!(c[64].abs() < 1e-7);
        assert!((c[32] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn symmetry() {
        for w in [Window::Hann, Window::Hamming, Window::Blackman] {
            let c = w.coefficients(64);
            for i in 0..32 {
                assert!((c[i] - c[63 - i]).abs() < 1e-6, "{w:?} at {i}");
            }
        }
    }

    #[test]
    fn known_gains() {
        // Hann: coherent 0.5, power 0.375 (asymptotically).
        assert!((Window::Hann.coherent_gain(4096) - 0.5).abs() < 1e-3);
        assert!((Window::Hann.power_gain(4096) - 0.375).abs() < 1e-3);
        assert!((Window::Rectangular.power_gain(128) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn hann_reduces_leakage() {
        // A tone at a non-integer bin leaks; windowing must concentrate
        // the far-field energy by orders of magnitude.
        let n = 256;
        let freq = 10.37; // deliberately off-bin
        let sig: Vec<f32> = (0..n)
            .map(|i| (2.0 * std::f32::consts::PI * freq * i as f32 / n as f32).sin())
            .collect();
        let spectrum = |x: &[f32]| -> Vec<f32> {
            let z: Vec<Complex32> = x.iter().map(|&v| c32(v, 0.0)).collect();
            FftPlanner::global()
                .plan_c2c(n, Direction::Forward)
                .transform(&z)
                .iter()
                .map(|c| c.abs())
                .collect()
        };
        let rect = spectrum(&sig);
        let mut tapered = sig.clone();
        apply(&mut tapered, &Window::Hann.coefficients(n));
        let hann = spectrum(&tapered);
        // Far from the tone (bin 60..120), Hann sidelobes must be much
        // lower than rectangular leakage.
        let far_rect: f32 = rect[60..120].iter().sum();
        let far_hann: f32 = hann[60..120].iter().sum();
        assert!(
            far_hann < far_rect / 50.0,
            "hann {far_hann} vs rect {far_rect}"
        );
    }

    #[test]
    #[should_panic]
    fn apply_length_mismatch_panics() {
        apply(&mut [1.0, 2.0], &[1.0]);
    }

    #[test]
    fn write_coefficients_matches_the_allocating_form() {
        for w in [Window::Rectangular, Window::Hann, Window::Hamming, Window::Blackman] {
            let alloc = w.coefficients(128);
            let mut buf = [1.0f32; 128];
            w.write_coefficients(&mut buf);
            assert_eq!(alloc.as_slice(), &buf[..], "{w:?}");
        }
    }

    #[test]
    #[should_panic]
    fn write_coefficients_rejects_tiny_buffers() {
        Window::Hann.write_coefficients(&mut [0.0]);
    }

    #[test]
    fn parse_round_trips_every_name() {
        for w in [Window::Rectangular, Window::Hann, Window::Hamming, Window::Blackman] {
            assert_eq!(Window::parse(w.name()), Some(w));
        }
        assert_eq!(Window::parse("kaiser"), None);
    }
}
