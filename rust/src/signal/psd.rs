//! Welch power-spectral-density estimation — a production FFT-library
//! feature built entirely on the in-repo substrates (real FFT + windows),
//! used by the spectral-analysis example and as an application-level
//! correctness check of the transform stack.

use super::window::{apply, Window};
use crate::fft::{Direction, FftPlanner};

/// Welch estimator configuration.
#[derive(Clone, Copy, Debug)]
pub struct WelchConfig {
    /// Segment (frame) length; must be even with n/2 a power of two.
    pub segment: usize,
    /// Overlap in samples (classically segment/2).
    pub overlap: usize,
    pub window: Window,
    /// Sample rate, for physical frequency axes.
    pub sample_rate: f64,
}

impl WelchConfig {
    pub fn new(segment: usize) -> WelchConfig {
        WelchConfig { segment, overlap: segment / 2, window: Window::Hann, sample_rate: 1.0 }
    }
}

/// A PSD estimate over `segment/2 + 1` one-sided frequency bins.
#[derive(Clone, Debug)]
pub struct Psd {
    pub freqs: Vec<f64>,
    pub power: Vec<f64>,
    pub segments_used: usize,
}

impl Psd {
    /// Index (and frequency) of the strongest non-DC bin.
    pub fn peak(&self) -> (usize, f64) {
        let (idx, _) = self
            .power
            .iter()
            .enumerate()
            .skip(1)
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .expect("non-empty psd");
        (idx, self.freqs[idx])
    }
}

/// Welch's method: split into overlapping windowed segments, average the
/// per-segment periodograms, normalise by the window power gain.
pub fn welch(signal: &[f32], cfg: &WelchConfig) -> Psd {
    let seg = cfg.segment;
    assert!(seg >= 4, "segment too short");
    assert!(cfg.overlap < seg, "overlap must be smaller than the segment");
    assert!(signal.len() >= seg, "signal shorter than one segment");

    let plan = FftPlanner::global().plan_r2c(seg, Direction::Forward);
    let coeffs = cfg.window.coefficients(seg);
    let power_gain = cfg.window.power_gain(seg);
    let hop = seg - cfg.overlap;

    let mut acc = vec![0.0f64; seg / 2 + 1];
    let mut used = 0usize;
    let mut start = 0usize;
    while start + seg <= signal.len() {
        let mut frame: Vec<f32> = signal[start..start + seg].to_vec();
        apply(&mut frame, &coeffs);
        let spec = plan.transform(&frame);
        for (k, z) in spec.iter().enumerate() {
            // One-sided PSD: double the interior bins.
            let mult = if k == 0 || k == seg / 2 { 1.0 } else { 2.0 };
            acc[k] += mult * (z.norm_sqr() as f64);
        }
        used += 1;
        start += hop;
    }
    assert!(used > 0);
    let norm = 1.0 / (used as f64 * power_gain * seg as f64 * cfg.sample_rate);
    let power: Vec<f64> = acc.iter().map(|&p| p * norm).collect();
    let freqs: Vec<f64> =
        (0..=seg / 2).map(|k| k as f64 * cfg.sample_rate / seg as f64).collect();
    Psd { freqs, power, segments_used: used }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::signal::rng::XorShift64;

    fn sine(n: usize, freq: f64, fs: f64, amp: f32) -> Vec<f32> {
        (0..n)
            .map(|i| amp * (2.0 * std::f64::consts::PI * freq * i as f64 / fs).sin() as f32)
            .collect()
    }

    #[test]
    fn peak_at_tone_frequency() {
        let fs = 1024.0;
        let sig = sine(8192, 100.0, fs, 1.0);
        let mut cfg = WelchConfig::new(512);
        cfg.sample_rate = fs;
        let psd = welch(&sig, &cfg);
        let (_, f) = psd.peak();
        assert!((f - 100.0).abs() <= fs / 512.0, "peak at {f} Hz");
    }

    #[test]
    fn parseval_total_power() {
        // Total PSD integral ~ signal variance (A^2/2 for a sine).
        let fs = 256.0;
        let sig = sine(16384, 32.0, fs, 2.0);
        let mut cfg = WelchConfig::new(256);
        cfg.sample_rate = fs;
        let psd = welch(&sig, &cfg);
        let df = fs / 256.0;
        let total: f64 = psd.power.iter().map(|&p| p * df).sum();
        assert!((total - 2.0).abs() < 0.1, "total power {total} (want A^2/2 = 2)");
    }

    #[test]
    fn white_noise_is_flat() {
        let mut rng = XorShift64::new(11);
        let sig: Vec<f32> = (0..65536).map(|_| rng.next_gaussian() as f32).collect();
        let psd = welch(&sig, &WelchConfig::new(256));
        let mean: f64 = psd.power[1..128].iter().sum::<f64>() / 127.0;
        for (k, &p) in psd.power[1..128].iter().enumerate() {
            assert!(p > 0.3 * mean && p < 3.0 * mean, "bin {k}: {p} vs mean {mean}");
        }
    }

    #[test]
    fn averaging_reduces_variance() {
        let mut rng = XorShift64::new(12);
        let sig: Vec<f32> = (0..65536).map(|_| rng.next_gaussian() as f32).collect();
        let few = welch(&sig[..1024], &WelchConfig::new(256));
        let many = welch(&sig, &WelchConfig::new(256));
        let rel_var = |p: &Psd| {
            let m: f64 = p.power[1..].iter().sum::<f64>() / (p.power.len() - 1) as f64;
            p.power[1..].iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / m / m
        };
        assert!(many.segments_used > 10 * few.segments_used);
        assert!(rel_var(&many) < rel_var(&few));
    }

    #[test]
    fn two_tones_resolved() {
        let fs = 1000.0;
        let mut sig = sine(32768, 60.0, fs, 1.0);
        let t2 = sine(32768, 180.0, fs, 0.5);
        for (a, b) in sig.iter_mut().zip(&t2) {
            *a += b;
        }
        let mut cfg = WelchConfig::new(512);
        cfg.sample_rate = fs;
        let psd = welch(&sig, &cfg);
        let bin = |f: f64| (f * 512.0 / fs).round() as usize;
        let p60 = psd.power[bin(60.0)];
        let p180 = psd.power[bin(180.0)];
        let floor = psd.power[bin(400.0)];
        assert!(p60 > 3.0 * p180, "amplitude ordering");
        assert!(p180 > 100.0 * floor, "second tone above noise floor");
    }

    #[test]
    #[should_panic]
    fn rejects_overlap_ge_segment() {
        let cfg = WelchConfig { overlap: 256, ..WelchConfig::new(256) };
        welch(&vec![0.0; 1024], &cfg);
    }
}
