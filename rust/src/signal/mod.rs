//! Workload generators.
//!
//! The paper evaluates with the linear function f(x) = x (§6); the
//! examples exercise richer signals (tones, noise) through the same
//! pipeline.  The noise generator uses our own deterministic PRNG so
//! benchmark workloads are reproducible run-to-run.

pub mod psd;
pub mod rng;
pub mod window;

pub use psd::{welch, Psd, WelchConfig};
pub use rng::XorShift64;
pub use window::Window;

use crate::fft::complex::{c32, Complex32};

/// The paper's benchmark input: f(x) = x, purely real (§6).
pub fn ramp(n: usize) -> Vec<Complex32> {
    (0..n).map(|i| c32(i as f32, 0.0)).collect()
}

/// A pure complex exponential at bin `k` — transforms to a delta at `k`.
pub fn tone(n: usize, k: usize, amplitude: f32) -> Vec<Complex32> {
    (0..n)
        .map(|j| {
            Complex32::cis(2.0 * std::f32::consts::PI * (k * j % n) as f32 / n as f32)
                .scale(amplitude)
        })
        .collect()
}

/// Real-valued sinusoid at bin `k` with a phase.
pub fn sine(n: usize, k: usize, amplitude: f32, phase: f32) -> Vec<Complex32> {
    (0..n)
        .map(|j| {
            c32(
                amplitude
                    * (2.0 * std::f32::consts::PI * (k as f32) * (j as f32) / n as f32 + phase)
                        .sin(),
                0.0,
            )
        })
        .collect()
}

/// Sum of several real sinusoids: `(bin, amplitude)` pairs.
pub fn multi_tone(n: usize, tones: &[(usize, f32)]) -> Vec<Complex32> {
    let mut out = vec![Complex32::ZERO; n];
    for &(k, a) in tones {
        for (j, z) in out.iter_mut().enumerate() {
            z.re += a * (2.0 * std::f32::consts::PI * (k as f32) * (j as f32) / n as f32).sin();
        }
    }
    out
}

/// Additive white Gaussian noise (Box-Muller over the xorshift stream).
pub fn gaussian_noise(n: usize, sigma: f32, rng: &mut XorShift64) -> Vec<Complex32> {
    (0..n).map(|_| c32(sigma * rng.next_gaussian() as f32, 0.0)).collect()
}

/// Add noise in place.
pub fn add_noise(signal: &mut [Complex32], sigma: f32, rng: &mut XorShift64) {
    for z in signal.iter_mut() {
        z.re += sigma * rng.next_gaussian() as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{fft, Direction};

    #[test]
    fn ramp_matches_paper_definition() {
        let r = ramp(8);
        assert_eq!(r[0], c32(0.0, 0.0));
        assert_eq!(r[7], c32(7.0, 0.0));
        assert!(r.iter().all(|z| z.im == 0.0));
    }

    #[test]
    fn tone_transforms_to_delta() {
        let n = 64;
        let x = tone(n, 5, 1.0);
        let spec = fft(&x, Direction::Forward);
        // Forward convention exp(-i...) puts exp(+2 pi i 5 j / n) at bin 5.
        assert!(spec[5].abs() > 0.9 * n as f32);
        for (k, z) in spec.iter().enumerate() {
            if k != 5 {
                assert!(z.abs() < 1e-2 * n as f32, "leak at {k}");
            }
        }
    }

    #[test]
    fn sine_peaks_at_pm_k() {
        let n = 128;
        let x = sine(n, 10, 2.0, 0.0);
        let spec = fft(&x, Direction::Forward);
        assert!(spec[10].abs() > 0.9 * n as f32); // amplitude*n/2 = n
        assert!(spec[n - 10].abs() > 0.9 * n as f32);
    }

    #[test]
    fn multi_tone_superposition() {
        let n = 256;
        let x = multi_tone(n, &[(3, 1.0), (17, 0.5)]);
        let spec = fft(&x, Direction::Forward);
        assert!(spec[3].abs() > spec[17].abs());
        assert!(spec[17].abs() > 10.0 * spec[40].abs());
    }

    #[test]
    fn noise_is_deterministic_per_seed() {
        let mut r1 = XorShift64::new(42);
        let mut r2 = XorShift64::new(42);
        let a = gaussian_noise(100, 1.0, &mut r1);
        let b = gaussian_noise(100, 1.0, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn noise_moments_sane() {
        let mut rng = XorShift64::new(7);
        let x = gaussian_noise(20000, 2.0, &mut rng);
        let mean: f32 = x.iter().map(|z| z.re).sum::<f32>() / x.len() as f32;
        let var: f32 = x.iter().map(|z| (z.re - mean) * (z.re - mean)).sum::<f32>() / x.len() as f32;
        assert!(mean.abs() < 0.1, "mean {mean}");
        assert!((var - 4.0).abs() < 0.3, "var {var}");
    }
}
