"""Make the ``compile`` package importable when pytest runs from the
repository root (CI invokes ``python -m pytest python/tests``)."""

import pathlib
import sys

_PYTHON_DIR = pathlib.Path(__file__).resolve().parents[1]
if str(_PYTHON_DIR) not in sys.path:
    sys.path.insert(0, str(_PYTHON_DIR))
