"""L1 correctness: Pallas FFT kernels vs independent oracles.

This is the build-time analog of the paper's §6.2 portability/precision
study: the portable kernel must agree bin-by-bin with reference
implementations.  Tolerances are single-precision — the paper's library
is fp32-only, and so are our kernels.
"""

import numpy as np
import pytest

from compile.kernels import fft_kernels as fk
from compile.kernels import ref

LENGTHS = [8, 16, 32, 64, 128, 256, 512, 1024, 2048]
DIRECTIONS = [ref.SYCLFFT_FORWARD, ref.SYCLFFT_INVERSE]


def rng(seed=0):
    return np.random.default_rng(seed)


def rand_planar(n, batch=1, seed=0):
    g = rng(seed)
    return (
        g.standard_normal((batch, n)).astype(np.float32),
        g.standard_normal((batch, n)).astype(np.float32),
    )


def assert_spectra_close(got, want, n, rtol=2e-5):
    """Scale-aware comparison: fp32 FFT error grows ~ sqrt(log n) * |X|."""
    gr, gi = np.asarray(got[0], np.float64), np.asarray(got[1], np.float64)
    wr, wi = np.asarray(want[0], np.float64), np.asarray(want[1], np.float64)
    scale = max(np.abs(wr).max(), np.abs(wi).max(), 1.0)
    err = max(np.abs(gr - wr).max(), np.abs(gi - wi).max()) / scale
    assert err < rtol * max(1.0, np.sqrt(np.log2(n))), f"relative error {err}"


# --------------------------------------------------------------------------
# Planning (the paper's stage_sizes derivation)
# --------------------------------------------------------------------------

class TestPlan:
    @pytest.mark.parametrize("n", LENGTHS)
    def test_radices_multiply_to_n(self, n):
        prod = 1
        for r in fk.plan_radices(n):
            prod *= r
        assert prod == n

    @pytest.mark.parametrize("n", LENGTHS)
    def test_radices_are_2_4_8(self, n):
        assert set(fk.plan_radices(n)) <= {2, 4, 8}

    def test_radix8_greedy(self):
        assert fk.plan_radices(2048) == [8, 8, 8, 4]
        assert fk.plan_radices(8) == [8]
        assert fk.plan_radices(16) == [8, 2]
        assert fk.plan_radices(32) == [8, 4]

    @pytest.mark.parametrize("n", [0, 1, 3, 6, 12, 100])
    def test_rejects_non_pow2(self, n):
        with pytest.raises(ValueError):
            fk.plan_radices(n)

    @pytest.mark.parametrize("n", LENGTHS)
    def test_permutation_is_bijection(self, n):
        perm = fk.input_permutation(n)
        assert sorted(perm.tolist()) == list(range(n))

    def test_radix2_perm_is_bitrev(self):
        # For an all-radix-2 plan the digit reversal must equal classic
        # bit reversal (paper Fig. 1).
        n = 8
        perm = fk.digit_reversal_perm(n, [2, 2, 2])
        expect = [int(f"{i:03b}"[::-1], 2) for i in range(n)]
        assert perm.tolist() == expect

    @pytest.mark.parametrize("n", LENGTHS)
    def test_stage_twiddles_unit_modulus(self, n):
        m = 1
        for r in fk.plan_radices(n):
            twr, twi = fk.stage_twiddles(r, m, ref.SYCLFFT_FORWARD)
            np.testing.assert_allclose(twr**2 + twi**2, 1.0, rtol=1e-6)
            m *= r

    def test_stage0_twiddles_are_one(self):
        twr, twi = fk.stage_twiddles(8, 1, ref.SYCLFFT_FORWARD)
        np.testing.assert_allclose(twr, 1.0)
        np.testing.assert_allclose(twi, 0.0, atol=1e-12)


# --------------------------------------------------------------------------
# Fused kernel vs oracles (the paper's Fig. 4/5 agreement, at build time)
# --------------------------------------------------------------------------

class TestFusedKernel:
    @pytest.mark.parametrize("n", LENGTHS)
    @pytest.mark.parametrize("direction", DIRECTIONS)
    def test_vs_numpy_random(self, n, direction):
        re, im = rand_planar(n, batch=2, seed=n)
        fn = fk.make_fft1d(n, batch=2, direction=direction)
        assert_spectra_close(fn(re, im), ref.fft_numpy(re, im, direction), n)

    @pytest.mark.parametrize("n", LENGTHS)
    def test_vs_naive_dft_ramp(self, n):
        # The paper's exact workload: f(x) = x.
        re, im = ref.ramp_input(n)
        fn = fk.make_fft1d(n, batch=1)
        assert_spectra_close(fn(re, im), ref.dft_naive(re, im), n)

    @pytest.mark.parametrize("n", [8, 64, 512])
    def test_vs_recursive_ct(self, n):
        re, im = rand_planar(n, seed=1)
        fn = fk.make_fft1d(n, batch=1)
        assert_spectra_close(fn(re, im), ref.fft_recursive(re, im), n)

    @pytest.mark.parametrize("n", [16, 256, 2048])
    def test_roundtrip_identity(self, n):
        re, im = rand_planar(n, batch=2, seed=2)
        fwd = fk.make_fft1d(n, batch=2, direction=ref.SYCLFFT_FORWARD)
        inv = fk.make_fft1d(n, batch=2, direction=ref.SYCLFFT_INVERSE)
        rr, ri = inv(*fwd(re, im))
        assert_spectra_close((rr, ri), (re, im), n, rtol=1e-4)

    @pytest.mark.parametrize("n", [8, 128])
    def test_linearity(self, n):
        a_re, a_im = rand_planar(n, seed=3)
        b_re, b_im = rand_planar(n, seed=4)
        fn = fk.make_fft1d(n, batch=1)
        fa, fb = fn(a_re, a_im), fn(b_re, b_im)
        fsum = fn(a_re + b_re, a_im + b_im)
        assert_spectra_close(
            fsum, (np.asarray(fa[0]) + fb[0], np.asarray(fa[1]) + fb[1]), n)

    @pytest.mark.parametrize("n", [16, 1024])
    def test_impulse_is_flat(self, n):
        # FFT of a unit impulse is all-ones.
        re = np.zeros((1, n), np.float32)
        re[0, 0] = 1.0
        im = np.zeros((1, n), np.float32)
        gr, gi = fk.make_fft1d(n, batch=1)(re, im)
        np.testing.assert_allclose(np.asarray(gr), 1.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(gi), 0.0, atol=1e-5)

    @pytest.mark.parametrize("n", [16, 256])
    def test_constant_is_impulse(self, n):
        re = np.ones((1, n), np.float32)
        im = np.zeros((1, n), np.float32)
        gr, gi = fk.make_fft1d(n, batch=1)(re, im)
        expect = np.zeros(n)
        expect[0] = n
        np.testing.assert_allclose(np.asarray(gr)[0], expect, atol=1e-4 * n)

    def test_parseval(self):
        n = 512
        re, im = rand_planar(n, seed=5)
        gr, gi = fk.make_fft1d(n, batch=1)(re, im)
        t = np.sum(re.astype(np.float64) ** 2 + im.astype(np.float64) ** 2)
        f = np.sum(np.asarray(gr, np.float64) ** 2 + np.asarray(gi, np.float64) ** 2) / n
        assert abs(t - f) / t < 1e-5

    @pytest.mark.parametrize("batch", [1, 2, 4, 8])
    def test_batched_matches_single(self, batch):
        n = 128
        re, im = rand_planar(n, batch=batch, seed=6)
        got_r, got_i = fk.make_fft1d(n, batch=batch)(re, im)
        single = fk.make_fft1d(n, batch=1)
        for b in range(batch):
            sr, si = single(re[b:b + 1], im[b:b + 1])
            np.testing.assert_allclose(np.asarray(got_r)[b], np.asarray(sr)[0], rtol=1e-5, atol=1e-3)
            np.testing.assert_allclose(np.asarray(got_i)[b], np.asarray(si)[0], rtol=1e-5, atol=1e-3)

    @pytest.mark.parametrize("block_batch", [1, 2, 4])
    def test_block_batch_invariance(self, block_batch):
        # WG_FACTOR analog must not change results, only the VMEM tiling.
        n, batch = 64, 4
        re, im = rand_planar(n, batch=batch, seed=7)
        base = fk.make_fft1d(n, batch=batch, block_batch=batch)(re, im)
        tiled = fk.make_fft1d(n, batch=batch, block_batch=block_batch)(re, im)
        np.testing.assert_allclose(np.asarray(base[0]), np.asarray(tiled[0]), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(base[1]), np.asarray(tiled[1]), rtol=1e-6)


# --------------------------------------------------------------------------
# Staged pipeline (one kernel per stage — launch-overhead ablation)
# --------------------------------------------------------------------------

class TestStagedPipeline:
    @pytest.mark.parametrize("n", [8, 64, 2048])
    @pytest.mark.parametrize("direction", DIRECTIONS)
    def test_vs_numpy(self, n, direction):
        re, im = rand_planar(n, batch=2, seed=8)
        got = fk.fft1d_staged(re, im, direction)
        assert_spectra_close(got, ref.fft_numpy(re, im, direction), n)

    @pytest.mark.parametrize("n", [16, 512])
    def test_matches_fused(self, n):
        re, im = rand_planar(n, batch=1, seed=9)
        fused = fk.make_fft1d(n, batch=1)(re, im)
        staged = fk.fft1d_staged(re, im)
        np.testing.assert_allclose(
            np.asarray(fused[0]), np.asarray(staged[0]), rtol=1e-5, atol=1e-3)
        np.testing.assert_allclose(
            np.asarray(fused[1]), np.asarray(staged[1]), rtol=1e-5, atol=1e-3)


# --------------------------------------------------------------------------
# Individual butterflies (the paper's radix_2/4/8 member functions)
# --------------------------------------------------------------------------

class TestButterflies:
    @pytest.mark.parametrize("r", [2, 4, 8])
    @pytest.mark.parametrize("s", [-1, +1])
    def test_butterfly_is_r_point_dft(self, r, s):
        g = rng(r * 10 + s)
        tr = g.standard_normal((1, r, 1)).astype(np.float32)
        ti = g.standard_normal((1, r, 1)).astype(np.float32)
        out_r, out_i = fk.BUTTERFLIES[r](tr, ti, s)
        x = tr[0, :, 0] + 1j * ti[0, :, 0]
        w = np.exp(s * 2j * np.pi * np.outer(np.arange(r), np.arange(r)) / r)
        want = w @ x
        np.testing.assert_allclose(np.asarray(out_r)[0, :, 0], want.real, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(out_i)[0, :, 0], want.imag, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("r,m", [(2, 4), (4, 2), (8, 8)])
    def test_apply_stage_shape_preserved(self, r, m):
        n = r * m * 2
        g = rng(0)
        xr = g.standard_normal((3, n)).astype(np.float32)
        xi = g.standard_normal((3, n)).astype(np.float32)
        twr, twi = fk.stage_twiddles(r, m, ref.SYCLFFT_FORWARD)
        or_, oi_ = fk.apply_stage(xr, xi, r, m, twr, twi, ref.SYCLFFT_FORWARD)
        assert or_.shape == (3, n) and oi_.shape == (3, n)


# --------------------------------------------------------------------------
# Oracle self-consistency (tests the tests)
# --------------------------------------------------------------------------

class TestOracles:
    @pytest.mark.parametrize("n", [8, 64, 256])
    @pytest.mark.parametrize("direction", DIRECTIONS)
    def test_naive_vs_numpy(self, n, direction):
        re, im = rand_planar(n, seed=11)
        a = ref.dft_naive(re, im, direction)
        b = ref.fft_numpy(re, im, direction)
        np.testing.assert_allclose(a[0], b[0], rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(a[1], b[1], rtol=1e-9, atol=1e-9)

    @pytest.mark.parametrize("n", [8, 64, 256])
    def test_recursive_vs_numpy(self, n):
        re, im = rand_planar(n, seed=12)
        a = ref.fft_recursive(re, im)
        b = ref.fft_numpy(re, im)
        np.testing.assert_allclose(a[0], b[0], rtol=1e-9, atol=1e-9)

    def test_jnp_native_vs_numpy(self):
        n = 128
        re, im = rand_planar(n, seed=13)
        a = ref.fft_jnp_native(re, im)
        b = ref.fft_numpy(re, im)
        np.testing.assert_allclose(np.asarray(a[0]), b[0], rtol=1e-4, atol=1e-3)
