"""2D FFT (paper §7 future work) — L2 composition of the L1 kernel."""

import numpy as np
import pytest

from compile import model
from compile.kernels.ref import SYCLFFT_FORWARD, SYCLFFT_INVERSE


def rand_image(h, w, seed=0):
    g = np.random.default_rng(seed)
    return (
        g.standard_normal((h, w)).astype(np.float32),
        g.standard_normal((h, w)).astype(np.float32),
    )


def rel_err(got, want):
    gr, gi = np.asarray(got[0], np.float64), np.asarray(got[1], np.float64)
    scale = np.abs(want).max()
    return max(np.abs(gr - want.real).max(), np.abs(gi - want.imag).max()) / scale


class TestFft2d:
    @pytest.mark.parametrize("h,w", [(8, 8), (32, 32), (16, 64), (64, 16)])
    @pytest.mark.parametrize("variant", ["pallas", "native"])
    def test_forward_matches_numpy(self, h, w, variant):
        re, im = rand_image(h, w, seed=h * w)
        got = model.fft2d_planar(re, im, SYCLFFT_FORWARD, variant)
        want = np.fft.fft2(re.astype(np.float64) + 1j * im.astype(np.float64))
        assert rel_err(got, want) < 1e-4

    @pytest.mark.parametrize("variant", ["pallas", "native"])
    def test_inverse_matches_numpy(self, variant):
        re, im = rand_image(16, 32, seed=3)
        got = model.fft2d_planar(re, im, SYCLFFT_INVERSE, variant)
        want = np.fft.ifft2(re.astype(np.float64) + 1j * im.astype(np.float64))
        assert rel_err(got, want) < 1e-4

    def test_roundtrip(self):
        re, im = rand_image(32, 32, seed=4)
        f = model.fft2d_planar(re, im, SYCLFFT_FORWARD, "pallas")
        b = model.fft2d_planar(np.asarray(f[0]), np.asarray(f[1]), SYCLFFT_INVERSE, "pallas")
        np.testing.assert_allclose(np.asarray(b[0]), re, atol=1e-3)
        np.testing.assert_allclose(np.asarray(b[1]), im, atol=1e-3)

    def test_variants_agree(self):
        re, im = rand_image(32, 64, seed=5)
        a = model.fft2d_planar(re, im, SYCLFFT_FORWARD, "pallas")
        b = model.fft2d_planar(re, im, SYCLFFT_FORWARD, "native")
        scale = np.abs(np.asarray(b[0])).max()
        assert np.abs(np.asarray(a[0]) - np.asarray(b[0])).max() / scale < 1e-4

    def test_unknown_variant_raises(self):
        re, im = rand_image(8, 8)
        with pytest.raises(ValueError):
            model.fft2d_planar(re, im, SYCLFFT_FORWARD, "naive")

    def test_lowerable(self):
        import jax
        from compile import aot

        fn = model.make_fn_2d(32, 32, SYCLFFT_FORWARD, "pallas")
        import jax.numpy as jnp

        spec = jax.ShapeDtypeStruct((32, 32), jnp.float32)
        text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
        assert "HloModule" in text
        assert "{...}" not in text, "constants must not be elided"
