"""Structural performance invariants of the lowered artifacts (§Perf)."""

import numpy as np
import pytest

from compile import analysis
from compile.kernels import fft_kernels as fk


class TestCostModel:
    @pytest.mark.parametrize("n", [64, 512, 2048])
    def test_xla_flops_close_to_ideal(self, n):
        # The lowered kernel must not recompute: XLA's counted flops stay
        # within ~1.5x of the 5 N log2 N model (butterfly bookkeeping and
        # the gather account for the slack).
        a = analysis.analyze(n)
        assert 0.5 < a["flop_ratio"] < 1.5, a

    def test_flop_model_monotone(self):
        vals = [analysis.fft_flop_model(2**k, 1) for k in range(3, 12)]
        assert all(b > a for a, b in zip(vals, vals[1:]))

    @pytest.mark.parametrize("n", [8, 256, 2048])
    def test_vmem_under_budget(self, n):
        # The whole working set of one grid cell must fit comfortably in
        # a TPU core's ~16 MiB VMEM; our own budget is 4 MiB.
        bb = fk.default_block_batch(n, 8)
        assert analysis.vmem_footprint_bytes(n, bb) <= 4 * 1024 * 1024

    def test_stage_count_logarithmic(self):
        # Radix-8-first keeps stage count at ceil(log2(n)/3)-ish: 4 for
        # n=2048 instead of 11 radix-2 passes.
        a = analysis.analyze(2048)
        assert a["stages"] == 4

    def test_bytes_accessed_reported(self):
        a = analysis.analyze(128)
        assert a["bytes_accessed"] > 0

    def test_block_batch_scales_down_with_n(self):
        assert fk.default_block_batch(8, 1024) >= fk.default_block_batch(2048, 1024)
        for n in [8, 2048]:
            assert np.gcd(fk.default_block_batch(n, 24), 24) == fk.default_block_batch(n, 24)
