"""Hypothesis property sweeps over the Pallas kernel's shape/input space.

The deterministic tests pin known-answer cases; these sweep randomized
lengths, batches, tilings and input distributions and assert the kernel
agrees with the numpy oracle and satisfies FFT axioms.
"""

import numpy as np
import pytest

# hypothesis is optional in minimal environments; skip the whole module
# rather than fail collection when it is absent.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import fft_kernels as fk
from compile.kernels import ref

# Kernel construction dominates runtime in interpret mode; keep examples
# moderate but meaningful.
COMMON = dict(deadline=None, max_examples=20)

log2n = st.integers(min_value=3, max_value=11)
small_log2n = st.integers(min_value=3, max_value=8)
batches = st.sampled_from([1, 2, 4])
directions = st.sampled_from([ref.SYCLFFT_FORWARD, ref.SYCLFFT_INVERSE])
seeds = st.integers(min_value=0, max_value=2**31 - 1)
amplitudes = st.floats(min_value=1e-3, max_value=1e3)


def rand_planar(n, batch, seed, amp=1.0):
    g = np.random.default_rng(seed)
    re = (amp * g.standard_normal((batch, n))).astype(np.float32)
    im = (amp * g.standard_normal((batch, n))).astype(np.float32)
    return re, im


def rel_err(got, want):
    gr, gi = np.asarray(got[0], np.float64), np.asarray(got[1], np.float64)
    wr, wi = np.asarray(want[0], np.float64), np.asarray(want[1], np.float64)
    scale = max(np.abs(wr).max(), np.abs(wi).max(), 1e-30)
    return max(np.abs(gr - wr).max(), np.abs(gi - wi).max()) / scale


@settings(**COMMON)
@given(k=log2n, batch=batches, direction=directions, seed=seeds, amp=amplitudes)
def test_kernel_matches_numpy(k, batch, direction, seed, amp):
    n = 2 ** k
    re, im = rand_planar(n, batch, seed, amp)
    fn = fk.make_fft1d(n, batch=batch, direction=direction)
    assert rel_err(fn(re, im), ref.fft_numpy(re, im, direction)) < 1e-4


@settings(**COMMON)
@given(k=small_log2n, seed=seeds)
def test_roundtrip_recovers_input(k, seed):
    n = 2 ** k
    re, im = rand_planar(n, 1, seed)
    fwd = fk.make_fft1d(n, batch=1, direction=ref.SYCLFFT_FORWARD)
    inv = fk.make_fft1d(n, batch=1, direction=ref.SYCLFFT_INVERSE)
    assert rel_err(inv(*fwd(re, im)), (re, im)) < 1e-4


@settings(**COMMON)
@given(k=small_log2n, seed=seeds, shift=st.integers(min_value=1, max_value=63))
def test_time_shift_preserves_magnitude(k, seed, shift):
    # |FFT(roll(x))| == |FFT(x)| — the shift theorem.
    n = 2 ** k
    shift = shift % n
    re, im = rand_planar(n, 1, seed)
    fn = fk.make_fft1d(n, batch=1)
    ar, ai = (np.asarray(v, np.float64) for v in fn(re, im))
    br, bi = (np.asarray(v, np.float64)
              for v in fn(np.roll(re, shift, -1), np.roll(im, shift, -1)))
    mag_a = np.hypot(ar, ai)
    mag_b = np.hypot(br, bi)
    scale = mag_a.max() + 1e-30
    assert np.abs(mag_a - mag_b).max() / scale < 1e-4


@settings(**COMMON)
@given(k=small_log2n, seed=seeds, scale=st.floats(min_value=-100, max_value=100))
def test_scaling_homogeneity(k, seed, scale):
    n = 2 ** k
    re, im = rand_planar(n, 1, seed)
    fn = fk.make_fft1d(n, batch=1)
    ar, ai = fn(re, im)
    br, bi = fn(np.float32(scale) * re, np.float32(scale) * im)
    want = (np.float32(scale) * np.asarray(ar), np.float32(scale) * np.asarray(ai))
    assert rel_err((br, bi), want) < 1e-4


@settings(**COMMON)
@given(k=st.integers(min_value=3, max_value=11))
def test_permutation_bijective_and_involution_for_pure_radix(k):
    n = 2 ** k
    perm = fk.input_permutation(n)
    assert sorted(perm.tolist()) == list(range(n))
    # Pure bit-reversal (all radix-2) is an involution.
    br = fk.digit_reversal_perm(n, [2] * k)
    assert (br[br] == np.arange(n)).all()


@settings(**COMMON)
@given(k=small_log2n, direction=directions)
def test_stage_twiddle_group_structure(k, direction):
    # w_{rm}^{p j} must satisfy w[p1+p2 mod .] relations: check unit modulus
    # and first-row/col identity for every stage of the plan.
    n = 2 ** k
    m = 1
    for r in fk.plan_radices(n):
        twr, twi = fk.stage_twiddles(r, m, direction)
        np.testing.assert_allclose(twr**2 + twi**2, 1.0, rtol=1e-5)
        np.testing.assert_allclose(twr[0], 1.0)
        np.testing.assert_allclose(twr[:, 0], 1.0)
        m *= r


@settings(**COMMON)
@given(k=small_log2n, seed=seeds, direction=directions)
def test_staged_equals_fused(k, seed, direction):
    n = 2 ** k
    re, im = rand_planar(n, 1, seed)
    fused = fk.make_fft1d(n, batch=1, direction=direction)(re, im)
    staged = fk.fft1d_staged(re, im, direction)
    assert rel_err(staged, (np.asarray(fused[0]), np.asarray(fused[1]))) < 1e-5


@settings(**COMMON)
@given(k=small_log2n, seed=seeds)
def test_conjugate_symmetry_for_real_input(k, seed):
    # Real input => X[n-k] = conj(X[k]).
    n = 2 ** k
    g = np.random.default_rng(seed)
    re = g.standard_normal((1, n)).astype(np.float32)
    im = np.zeros((1, n), np.float32)
    gr, gi = (np.asarray(v, np.float64) for v in fk.make_fft1d(n, batch=1)(re, im))
    idx = (-np.arange(n)) % n
    scale = np.abs(gr).max() + 1e-30
    assert np.abs(gr[0, idx] - gr[0]).max() / scale < 1e-4
    assert np.abs(gi[0, idx] + gi[0]).max() / scale < 1e-4
