"""L2 tests: variant functions, plan metadata and AOT lowering."""

import json
import os
import tempfile

import jax
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def rand_planar(n, batch=1, seed=0):
    g = np.random.default_rng(seed)
    return (
        g.standard_normal((batch, n)).astype(np.float32),
        g.standard_normal((batch, n)).astype(np.float32),
    )


class TestVariants:
    @pytest.mark.parametrize("variant", model.VARIANTS)
    @pytest.mark.parametrize("direction", ["fwd", "inv"])
    def test_variant_matches_oracle(self, variant, direction):
        n, batch = 64, 2
        d = model.DIRECTIONS[direction]
        re, im = rand_planar(n, batch, seed=42)
        fn = model.make_fn(n, batch, d, variant)
        gr, gi = fn(re, im)
        wr, wi = ref.fft_numpy(re, im, d)
        scale = max(np.abs(wr).max(), 1.0)
        assert np.abs(np.asarray(gr, np.float64) - wr).max() / scale < 1e-4
        assert np.abs(np.asarray(gi, np.float64) - wi).max() / scale < 1e-4

    def test_unknown_variant_raises(self):
        with pytest.raises(ValueError):
            model.make_fn(8, 1, ref.SYCLFFT_FORWARD, "cufft")

    @pytest.mark.parametrize("variant", model.VARIANTS)
    def test_jit_traceable(self, variant):
        n = 16
        fn = jax.jit(model.make_fn(n, 1, ref.SYCLFFT_FORWARD, variant))
        re, im = rand_planar(n)
        gr, gi = fn(re, im)
        assert gr.shape == (1, n) and gi.shape == (1, n)

    def test_variants_agree_pairwise(self):
        # The §6.2 portability claim at build time: all implementations
        # produce the same spectrum for the paper's workload.
        n = 256
        re, im = model.ramp(n)
        outs = {}
        for v in model.VARIANTS:
            gr, gi = model.make_fn(n, 1, ref.SYCLFFT_FORWARD, v)(re, im)
            outs[v] = (np.asarray(gr, np.float64), np.asarray(gi, np.float64))
        scale = np.abs(outs["native"][0]).max()
        for v in ("pallas", "naive"):
            assert np.abs(outs[v][0] - outs["native"][0]).max() / scale < 1e-4
            assert np.abs(outs[v][1] - outs["native"][1]).max() / scale < 1e-4


class TestStageSizes:
    @pytest.mark.parametrize("n", model.PAPER_LENGTHS)
    def test_cover_n(self, n):
        sizes = model.stage_sizes(n)
        assert sizes[0][1] == 1
        prod = 1
        for r, m in sizes:
            assert m == prod
            prod *= r
        assert prod == n

    def test_paper_example(self):
        assert model.stage_sizes(2048) == [(8, 1), (8, 8), (8, 64), (4, 512)]


class TestStagePieces:
    def test_bitrev_then_stages_equals_fft(self):
        n, batch = 64, 1
        re, im = rand_planar(n, batch, seed=7)
        r_, i_ = model.make_stage_fn(n, batch, "bitrev")(re, im)
        for r, m in model.stage_sizes(n):
            r_, i_ = model.make_stage_fn(n, batch, f"stage:{r}:{m}")(r_, i_)
        wr, wi = ref.fft_numpy(re, im)
        scale = np.abs(wr).max()
        assert np.abs(np.asarray(r_, np.float64) - wr).max() / scale < 1e-4

    def test_scale_piece(self):
        n = 8
        re, im = rand_planar(n)
        r_, i_ = model.make_stage_fn(n, 1, "scale")(re, im)
        np.testing.assert_allclose(np.asarray(r_), re / n, rtol=1e-6)

    def test_unknown_piece_raises(self):
        with pytest.raises(ValueError):
            model.make_stage_fn(8, 1, "transpose")


class TestAot:
    def test_lower_produces_hlo_text(self):
        fn = model.make_fn(8, 1, ref.SYCLFFT_FORWARD, "pallas")
        text = aot.lower_fn(fn, 8, 1)
        assert "HloModule" in text
        assert "f32[1,8]" in text

    def test_native_variant_contains_fft_op(self):
        fn = model.make_fn(16, 1, ref.SYCLFFT_FORWARD, "native")
        text = aot.lower_fn(fn, 16, 1)
        assert "fft(" in text and "fft_type=FFT" in text

    def test_build_all_writes_manifest(self):
        with tempfile.TemporaryDirectory() as d:
            entries = aot.build_all(d, lengths=(8,), verbose=False)
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            assert manifest["abi"] == "planar-f32"
            assert len(manifest["artifacts"]) == len(entries)
            for e in entries:
                path = os.path.join(d, e["path"])
                assert os.path.exists(path), e
                with open(path) as f:
                    assert "HloModule" in f.read(100)

    def test_artifact_names_unique(self):
        with tempfile.TemporaryDirectory() as d:
            entries = aot.build_all(d, lengths=(8, 16), verbose=False)
            names = [e["name"] for e in entries]
            assert len(names) == len(set(names))
