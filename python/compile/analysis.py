"""L1/L2 profiling: HLO cost analysis and VMEM footprint estimates.

The CPU interpret-mode timings of a Pallas kernel say nothing about TPU
performance; what we *can* measure at build time is structural:

  * XLA's own cost model (flops / transcendentals / bytes accessed) for
    each lowered artifact — the L2 "no redundant recomputation" check;
  * the VMEM working set implied by the kernel's BlockSpec tiling — the
    L1 scheduling constraint (everything must stay on-chip);
  * arithmetic efficiency vs the 5*N*log2(N) FFT flop model.

Run: ``python -m compile.analysis [--n 2048]`` (from ``python/``).
"""

from __future__ import annotations

import argparse

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import fft_kernels as fk
from .kernels.ref import SYCLFFT_FORWARD


def hlo_cost(fn, n: int, batch: int) -> dict:
    """XLA cost-analysis properties of the optimized module."""
    spec_re, spec_im = model.example_inputs(n, batch)
    compiled = jax.jit(fn).lower(spec_re, spec_im).compile()
    # cost_analysis() returns {property: value} on recent jax.
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    return dict(cost) if cost else {}


def fft_flop_model(n: int, batch: int) -> float:
    """The standard 5 N log2 N real-flop count for a C2C FFT."""
    return 5.0 * batch * n * np.log2(n)


def vmem_footprint_bytes(n: int, block_batch: int) -> int:
    """Planar in + out + twiddles + permutation for one grid cell."""
    planes = 4 * block_batch * n * 4  # in/out x re/im, f32
    m, tw = 1, 0
    for r in fk.plan_radices(n):
        tw += 2 * r * m * 4
        m *= r
    perm = n * 4
    return planes + tw + perm


def analyze(n: int, batch: int = 1) -> dict:
    """Full structural profile for one (n, batch) pallas artifact."""
    fn = model.make_fn(n, batch, SYCLFFT_FORWARD, "pallas")
    cost = hlo_cost(fn, n, batch)
    flops = float(cost.get("flops", 0.0))
    ideal = fft_flop_model(n, batch)
    block_batch = fk.default_block_batch(n, batch)
    return {
        "n": n,
        "batch": batch,
        "stages": len(fk.plan_radices(n)),
        "xla_flops": flops,
        "model_flops": ideal,
        "flop_ratio": flops / ideal if ideal else float("nan"),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "block_batch": block_batch,
        "vmem_bytes": vmem_footprint_bytes(n, block_batch),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch", type=int, default=1)
    args = ap.parse_args()
    print(f"{'n':>6} {'stages':>6} {'xla flops':>12} {'5nlog2n':>10} "
          f"{'ratio':>6} {'bytes':>10} {'vmem KiB':>9}")
    for n in model.PAPER_LENGTHS:
        a = analyze(n, args.batch)
        print(f"{a['n']:>6} {a['stages']:>6} {a['xla_flops']:>12.0f} "
              f"{a['model_flops']:>10.0f} {a['flop_ratio']:>6.2f} "
              f"{a['bytes_accessed']:>10.0f} {a['vmem_bytes'] / 1024:>9.1f}")


if __name__ == "__main__":
    main()
