"""L2 — the JAX compute graph: FFT plans, variants and AOT entry points.

The paper's host code decides, per sequence length, the stage decomposition
(``stage_sizes``) and the kernel instantiation (``WG_FACTOR``), then
launches the SYCL kernel.  This module is the same role in JAX: it builds
the plan, composes the L1 Pallas kernels, and exposes one jittable
function per (length, batch, direction, variant) tuple, which ``aot.py``
lowers to an HLO-text artifact.

Variants (the paper's comparison axis — DESIGN.md §4):

  * ``pallas``  — the portable library under test (fused L1 kernel);
  * ``native``  — XLA's native ``fft`` HLO instruction (``jnp.fft``),
                  the vendor-optimised black box: our cuFFT/rocFFT analog;
  * ``naive``   — direct O(N^2) DFT (Eqn. 1 evaluated literally), the
                  lower baseline;
  * per-stage entry points (``bitrev``/``stage``) for the multi-kernel
    pipeline the Rust runtime drives kernel-by-kernel (launch-overhead
    ablation).

ABI: planar float32 ``(batch, n)`` real and imaginary planes in, same out.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .kernels import fft_kernels as fk
from .kernels.ref import SYCLFFT_FORWARD, SYCLFFT_INVERSE

VARIANTS = ("pallas", "native", "naive")
DIRECTIONS = {"fwd": SYCLFFT_FORWARD, "inv": SYCLFFT_INVERSE}

#: The paper's evaluated lengths: 2^3 .. 2^11 (§6).
PAPER_LENGTHS = tuple(2 ** k for k in range(3, 12))


def stage_sizes(n: int) -> list[tuple[int, int]]:
    """The paper's ``stage_sizes``: [(radix, m)] in execution order."""
    out, m = [], 1
    for r in fk.plan_radices(n):
        out.append((r, m))
        m *= r
    return out


def fft_native(re, im, direction: int):
    """Vendor-analog variant: XLA's own FFT instruction."""
    x = jnp.asarray(re, jnp.float32) + 1j * jnp.asarray(im, jnp.float32)
    out = jnp.fft.fft(x, axis=-1) if direction == SYCLFFT_FORWARD else jnp.fft.ifft(x, axis=-1)
    return jnp.real(out).astype(jnp.float32), jnp.imag(out).astype(jnp.float32)


def fft_naive(re, im, direction: int):
    """Direct O(N^2) DFT built from runtime-computed trig tables.

    The DFT matrix is expressed with jnp ops (not baked constants) so the
    HLO text stays small; XLA constant-folds it at compile time on the
    Rust side.
    """
    n = re.shape[-1]
    k = jnp.arange(n, dtype=jnp.float32)
    ang = direction * 2.0 * jnp.pi / n * jnp.outer(k, k)
    wr, wi = jnp.cos(ang), jnp.sin(ang)
    out_re = re @ wr.T - im @ wi.T
    out_im = re @ wi.T + im @ wr.T
    if direction == SYCLFFT_INVERSE:
        out_re, out_im = out_re / n, out_im / n
    return out_re, out_im


def make_fn(n: int, batch: int, direction: int, variant: str):
    """Build the jittable planar FFT function for one artifact."""
    if variant == "pallas":
        pallas_fn = fk.make_fft1d(n, batch=batch, direction=direction)

        def fn(re, im):
            return pallas_fn(re, im)
    elif variant == "native":
        def fn(re, im):
            return fft_native(re, im, direction)
    elif variant == "naive":
        def fn(re, im):
            return fft_naive(re, im, direction)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    return fn


def fft2d_planar(re, im, direction: int, variant: str):
    """2D C2C transform of an (h, w) planar image — the paper's §7
    "multidimensional inputs" future work.

    The ``pallas`` variant composes the 1D L1 kernel row-column (rows as
    the batch axis, transpose, columns, transpose back), so the 2D
    feature reuses the exact kernel under test; ``native`` lowers XLA's
    own 2D FFT.
    """
    h, w = re.shape
    if variant == "pallas":
        rows = fk.make_fft1d(w, batch=h, direction=direction)
        re, im = rows(re, im)
        re, im = re.T, im.T
        cols = fk.make_fft1d(h, batch=w, direction=direction)
        re, im = cols(re, im)
        return re.T, im.T
    if variant == "native":
        x = jnp.asarray(re, jnp.float32) + 1j * jnp.asarray(im, jnp.float32)
        out = jnp.fft.fft2(x) if direction == SYCLFFT_FORWARD else jnp.fft.ifft2(x)
        return jnp.real(out).astype(jnp.float32), jnp.imag(out).astype(jnp.float32)
    raise ValueError(f"unknown 2d variant {variant!r}")


def make_fn_2d(h: int, w: int, direction: int, variant: str):
    """Jittable (h, w) planar 2D FFT for one artifact."""
    def fn(re, im):
        return fft2d_planar(re, im, direction, variant)

    return fn


def make_stage_fn(n: int, batch: int, kind: str, direction: int = SYCLFFT_FORWARD):
    """Entry points for the staged (multi-launch) pipeline.

    ``kind`` is ``"bitrev"``, ``"stage:<r>:<m>"`` or ``"scale"``.
    """
    if kind == "bitrev":
        call = fk.make_bitrev(n, batch)
        return lambda re, im: call(re, im)
    if kind == "scale":
        return lambda re, im: fk.normalize_inverse(re, im, n)
    if kind.startswith("stage:"):
        _, r, m = kind.split(":")
        call = fk.make_stage(n, int(r), int(m), batch, direction)
        return lambda re, im: call(re, im)
    raise ValueError(f"unknown stage kind {kind!r}")


def example_inputs(n: int, batch: int):
    """Shape/dtype specs used to trace the functions for lowering."""
    import jax

    spec = jax.ShapeDtypeStruct((batch, n), jnp.float32)
    return spec, spec


def ramp(n: int, batch: int = 1):
    """The paper's benchmark input f(x) = x (§6), planar."""
    re = np.tile(np.arange(n, dtype=np.float32), (batch, 1))
    im = np.zeros((batch, n), dtype=np.float32)
    return re, im
