"""AOT compiler: lower every (length, batch, direction, variant) to HLO text.

Python runs exactly once (``make artifacts``); the Rust runtime loads the
HLO text via ``HloModuleProto::from_text_file``, compiles it on the PJRT
CPU client and serves it from then on — Python is never on the request
path.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs:
    artifacts/<name>.hlo.txt       one per artifact
    artifacts/manifest.json        index consumed by rust/src/plan/manifest.rs
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.ref import SYCLFFT_FORWARD

#: Batch sizes emitted for the portable and vendor-analog variants.  The
#: singleton batch reproduces the paper's measurements; the larger batches
#: feed the Rust coordinator's dynamic batcher, which picks the
#: tightest-fitting artifact per launch (coordinator/worker.rs) — the
#: full sweep gives the padding-vs-launch trade-off more than two points.
BATCHES = (1, 2, 4, 8, 16, 32)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps with ``to_tuple2``).

    ``print_large_constants=True`` is essential: the default printer
    elides arrays beyond a few elements as ``{...}``, which the 0.5.1
    text parser silently zero-fills — the permutation and twiddle tables
    would vanish from every kernel with n > 8.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # The 0.5.1 text parser predates newer metadata attributes
    # (source_end_line etc.); strip metadata entirely.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def lower_fn(fn, n: int, batch: int) -> str:
    spec_re, spec_im = model.example_inputs(n, batch)
    return to_hlo_text(jax.jit(fn).lower(spec_re, spec_im))


def artifact_name(n: int, batch: int, direction: str, variant: str) -> str:
    return f"fft_{variant}_n{n}_b{batch}_{direction}"


def stage_artifact_name(n: int, batch: int, kind: str) -> str:
    return f"fft_piece_n{n}_b{batch}_{kind.replace(':', '_')}"


def build_all(out_dir: str, lengths=model.PAPER_LENGTHS, verbose: bool = True):
    os.makedirs(out_dir, exist_ok=True)
    entries = []

    # -- full-transform artifacts -----------------------------------------
    for variant in model.VARIANTS:
        for n in lengths:
            for batch in BATCHES:
                if variant == "naive" and batch != 1:
                    continue  # baseline only needs the paper's batch=1
                for dname, direction in model.DIRECTIONS.items():
                    name = artifact_name(n, batch, dname, variant)
                    fn = model.make_fn(n, batch, direction, variant)
                    text = lower_fn(fn, n, batch)
                    path = os.path.join(out_dir, f"{name}.hlo.txt")
                    with open(path, "w") as f:
                        f.write(text)
                    entries.append({
                        "name": name,
                        "kind": "full",
                        "variant": variant,
                        "n": n,
                        "batch": batch,
                        "direction": dname,
                        "path": f"{name}.hlo.txt",
                        "stages": [list(s) for s in model.stage_sizes(n)],
                    })
                    if verbose:
                        print(f"  {name}: {len(text)} chars")

    # -- 2D artifacts (paper §7 future work: multidimensional inputs) -----
    shapes_2d = [(32, 32), (64, 64), (32, 128)]
    for variant in ("pallas", "native"):
        for h, w in shapes_2d:
            if max(h, w) > max(lengths):
                continue
            for dname, direction in model.DIRECTIONS.items():
                name = f"fft2d_{variant}_{h}x{w}_{dname}"
                fn = model.make_fn_2d(h, w, direction, variant)
                spec = jax.ShapeDtypeStruct((h, w), jnp.float32)
                text = to_hlo_text(jax.jit(fn).lower(spec, spec))
                path = os.path.join(out_dir, f"{name}.hlo.txt")
                with open(path, "w") as f:
                    f.write(text)
                entries.append({
                    "name": name,
                    "kind": "full2d",
                    "variant": variant,
                    "n": w,
                    "batch": h,
                    "dims": [h, w],
                    "direction": dname,
                    "path": f"{name}.hlo.txt",
                })
                if verbose:
                    print(f"  {name}: {len(text)} chars")

    # -- per-stage artifacts for the multi-launch pipeline (n = 2^11) -----
    n = max(lengths)
    kinds = ["bitrev"] + [f"stage:{r}:{m}" for r, m in model.stage_sizes(n)]
    for kind in kinds:
        name = stage_artifact_name(n, 1, kind)
        fn = model.make_stage_fn(n, 1, kind)
        text = lower_fn(fn, n, 1)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entries.append({
            "name": name,
            "kind": "piece",
            "variant": "pallas_staged",
            "n": n,
            "batch": 1,
            "direction": "fwd",
            "piece": kind,
            "path": f"{name}.hlo.txt",
        })
        if verbose:
            print(f"  {name}: {len(text)} chars")

    manifest = {
        "abi": "planar-f32",
        "return_tuple": True,
        "lengths": list(lengths),
        "artifacts": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote {len(entries)} artifacts + manifest to {out_dir}")
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--max-log2", type=int, default=11,
                    help="largest log2 length to emit (paper: 11)")
    args = ap.parse_args()
    lengths = tuple(2 ** k for k in range(3, args.max_log2 + 1))
    build_all(args.out, lengths)


if __name__ == "__main__":
    main()
