"""Pure-jnp / numpy correctness oracles for the FFT kernels.

The paper validates SYCL-FFT against vendor libraries (cuFFT, rocFFT)
bin-by-bin.  At build time we validate the L1 Pallas kernels against three
independent oracles:

  * ``dft_naive``     — direct O(N^2) evaluation of Eqn. (1) of the paper,
  * ``fft_recursive`` — textbook recursive radix-2 Cooley-Tukey (Eqns 3-6),
  * ``fft_numpy``     — the battle-tested upstream implementation.

All oracles use the *planar* complex representation ``(re, im)`` of
float arrays with the transform along the last axis, matching the kernel
ABI (the paper's ``float2`` buffers, split into two planes so that the
Rust <-> HLO boundary only ever carries real f32 literals).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

#: Direction constants, mirroring the paper's SYCLFFT_FORWARD / SYCLFFT_INVERSE.
SYCLFFT_FORWARD = -1
SYCLFFT_INVERSE = +1


def dft_matrix(n: int, direction: int = SYCLFFT_FORWARD):
    """Real/imaginary parts of the length-``n`` DFT matrix.

    ``W[k, j] = exp(direction * 2i*pi*k*j / n)`` — Eqn. (1) of the paper
    uses ``direction = -1`` (forward); the inverse (Eqn. 2) flips the sign
    and applies a ``1/n`` normalisation (handled by the caller).
    """
    k = np.arange(n).reshape(-1, 1)
    j = np.arange(n).reshape(1, -1)
    ang = direction * 2.0 * np.pi * k * j / n
    return np.cos(ang), np.sin(ang)


def dft_naive(re, im, direction: int = SYCLFFT_FORWARD):
    """Direct O(N^2) DFT over the last axis, float64 internally.

    This is the paper's Eqn. (1)/(2) evaluated literally; it is the
    highest-authority oracle because it contains no algorithmic cleverness
    to get wrong.
    """
    re = np.asarray(re, dtype=np.float64)
    im = np.asarray(im, dtype=np.float64)
    n = re.shape[-1]
    wr, wi = dft_matrix(n, direction)
    out_re = re @ wr.T - im @ wi.T
    out_im = re @ wi.T + im @ wr.T
    if direction == SYCLFFT_INVERSE:
        out_re = out_re / n
        out_im = out_im / n
    return out_re, out_im


def fft_recursive(re, im, direction: int = SYCLFFT_FORWARD):
    """Textbook recursive radix-2 Cooley-Tukey (paper Eqns. 3-6)."""
    x = np.asarray(re, dtype=np.float64) + 1j * np.asarray(im, dtype=np.float64)

    def rec(v: np.ndarray) -> np.ndarray:
        n = v.shape[-1]
        if n == 1:
            return v
        even = rec(v[..., 0::2])
        odd = rec(v[..., 1::2])
        k = np.arange(n // 2)
        w = np.exp(direction * 2j * np.pi * k / n)
        t = w * odd
        return np.concatenate([even + t, even - t], axis=-1)

    out = rec(x)
    if direction == SYCLFFT_INVERSE:
        out = out / x.shape[-1]
    return out.real, out.imag


def fft_numpy(re, im, direction: int = SYCLFFT_FORWARD):
    """numpy.fft oracle in the planar ABI."""
    x = np.asarray(re, dtype=np.float64) + 1j * np.asarray(im, dtype=np.float64)
    out = np.fft.fft(x, axis=-1) if direction == SYCLFFT_FORWARD else np.fft.ifft(x, axis=-1)
    return out.real, out.imag


def fft_jnp_native(re, im, direction: int = SYCLFFT_FORWARD):
    """jnp.fft in the planar ABI — the 'vendor library' variant's own math.

    This is what the ``native`` AOT variant lowers (XLA's ``fft`` HLO
    instruction): a vendor-optimised black box from the portable library's
    point of view — our cuFFT/rocFFT analog, see DESIGN.md §4.
    """
    x = jnp.asarray(re, jnp.float32) + 1j * jnp.asarray(im, jnp.float32)
    out = jnp.fft.fft(x, axis=-1) if direction == SYCLFFT_FORWARD else jnp.fft.ifft(x, axis=-1)
    return jnp.real(out).astype(jnp.float32), jnp.imag(out).astype(jnp.float32)


def ramp_input(n: int, batch: int = 1):
    """The paper's evaluation workload: f(x) = x (§6), zero imaginary part."""
    re = np.tile(np.arange(n, dtype=np.float32), (batch, 1))
    im = np.zeros((batch, n), dtype=np.float32)
    return re, im
