"""L1 — Pallas FFT kernels (the analog of the paper's SYCL ``fft1d`` functor).

The paper implements a single-source SYCL kernel that computes a 1D C2C
FFT with a host-computed stage list (``stage_sizes``), explicit
``radix_2`` / ``radix_4`` / ``radix_8`` member functions, and the whole
sequence staged through work-group local memory.

TPU/Pallas adaptation (DESIGN.md §3):

  * one SYCL *work-group* transforming one sequence in local memory
    becomes one Pallas *grid cell* transforming a tile of sequences held
    entirely in VMEM (N <= 2^11 -> the whole problem fits in one block);
  * per-work-item butterflies become *vectorised* stage updates — each
    stage reshapes the sequence to ``(blocks, radix, m)`` and performs the
    radix-r combine on whole lanes at once (VPU instead of SIMT);
  * the paper's ``float2`` local buffers become planar ``(re, im)`` f32
    arrays, so the Rust <-> HLO boundary carries only real literals;
  * ``stage_sizes`` is evaluated at trace time and the stage loop is
    fully unrolled — every artifact is shape-specialised, exactly like
    the paper's per-``WG_FACTOR`` kernel instantiation;
  * twiddle factors are produced outside the kernel (the paper computes
    them "a priori on the host") and passed in as kernel operands.

All kernels are lowered with ``interpret=True``: real-TPU Pallas lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute, while the
interpret path lowers to plain HLO that runs anywhere (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .ref import SYCLFFT_FORWARD, SYCLFFT_INVERSE

#: Inverse of sqrt(2), used by the radix-8 butterfly (w8^1 = (1 ± i)/sqrt 2).
INV_SQRT2 = 1.0 / math.sqrt(2.0)


# --------------------------------------------------------------------------
# Planning: the paper's host-side ``stage_sizes`` computation.
# --------------------------------------------------------------------------

def plan_radices(n: int) -> list[int]:
    """Greedy radix-8-first decomposition of a power-of-two length.

    Mirrors the paper's host-side derivation of ``stage_sizes`` — "the
    sequence of radix function calls" (§4).  Radix-8 stages are preferred
    because they minimise both stage count and twiddle traffic; the
    remainder is a single radix-4 or radix-2 stage.

    The returned list is in *execution* order: the first entry is the
    innermost (smallest-butterfly) stage.
    """
    if n < 2 or n & (n - 1):
        raise ValueError(f"sequence length must be a power of two >= 2, got {n}")
    k = n.bit_length() - 1
    radices: list[int] = []
    while k >= 3:
        radices.append(8)
        k -= 3
    if k == 2:
        radices.append(4)
    elif k == 1:
        radices.append(2)
    return radices


def digit_reversal_perm(n: int, radices_outermost_first: list[int]) -> np.ndarray:
    """Mixed-radix digit-reversal permutation for a DIT decomposition.

    Generalises the radix-2 bit-reversal of Fig. 1 in the paper: with the
    outermost (final) stage of radix ``r``, the subsequence with indices
    ``== p (mod r)`` must land in contiguous block ``p``, recursively.
    """
    if not radices_outermost_first:
        assert n == 1
        return np.zeros(1, dtype=np.int32)
    r = radices_outermost_first[0]
    sub = digit_reversal_perm(n // r, radices_outermost_first[1:])
    return np.concatenate([sub * r + p for p in range(r)]).astype(np.int32)


def input_permutation(n: int) -> np.ndarray:
    """Digit-reversal permutation matching :func:`plan_radices` order."""
    return digit_reversal_perm(n, plan_radices(n)[::-1])


def stage_twiddles(r: int, m: int, direction: int) -> tuple[np.ndarray, np.ndarray]:
    """Twiddle factors ``w_{r*m}^{p*j}`` for a radix-``r`` stage of size ``m``.

    Shape ``(r, m)`` each for the real and imaginary planes; ``direction``
    is the sign of the exponent (paper's SYCLFFT_FORWARD = -1).
    """
    p = np.arange(r).reshape(-1, 1)
    j = np.arange(m).reshape(1, -1)
    ang = direction * 2.0 * np.pi * p * j / (r * m)
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)


# --------------------------------------------------------------------------
# Butterflies: the analogs of the paper's radix_2 / radix_4 / radix_8
# member functions (Listing 1).  Each takes planar tensors shaped
# (..., r, m) that have already been twiddled, and performs the radix-r
# DFT across the ``r`` axis with unrolled, constant-coefficient arithmetic.
# ``s`` is the direction sign: multiplication by i*s implements the
# paper's +/- i factors in Eqns. (13)-(14).
# --------------------------------------------------------------------------

def radix_2(tr, ti, s):
    """2-point butterfly: (t0 + t1, t0 - t1)."""
    del s  # radix-2 has no direction-dependent coefficient
    t0r, t1r = tr[..., 0, :], tr[..., 1, :]
    t0i, t1i = ti[..., 0, :], ti[..., 1, :]
    return (
        jnp.stack([t0r + t1r, t0r - t1r], axis=-2),
        jnp.stack([t0i + t1i, t0i - t1i], axis=-2),
    )


def radix_4(tr, ti, s):
    """4-point butterfly with w4 = exp(s*i*pi/2) = s*i (paper Eqns. 11-14)."""
    t0r, t1r, t2r, t3r = (tr[..., p, :] for p in range(4))
    t0i, t1i, t2i, t3i = (ti[..., p, :] for p in range(4))
    # even/odd partial sums
    a_r, a_i = t0r + t2r, t0i + t2i  # t0 + t2
    b_r, b_i = t0r - t2r, t0i - t2i  # t0 - t2
    c_r, c_i = t1r + t3r, t1i + t3i  # t1 + t3
    d_r, d_i = t1r - t3r, t1i - t3i  # t1 - t3
    # (i*s) * d  ==  (-s*d_i, s*d_r)
    id_r, id_i = -s * d_i, s * d_r
    return (
        jnp.stack([a_r + c_r, b_r + id_r, a_r - c_r, b_r - id_r], axis=-2),
        jnp.stack([a_i + c_i, b_i + id_i, a_i - c_i, b_i - id_i], axis=-2),
    )


def radix_8(tr, ti, s):
    """8-point butterfly: two radix-4 DFTs combined with w8^k twiddles.

    ``w8 = exp(s*i*pi/4) = (1 + s*i)/sqrt(2)``; the combine is
    ``X_k = E_k + w8^k O_k``, ``X_{k+4} = E_k - w8^k O_k``.
    """
    er, ei = radix_4(tr[..., 0::2, :], ti[..., 0::2, :], s)  # t0,t2,t4,t6
    orr, oi = radix_4(tr[..., 1::2, :], ti[..., 1::2, :], s)  # t1,t3,t5,t7

    e = [(er[..., k, :], ei[..., k, :]) for k in range(4)]
    o = [(orr[..., k, :], oi[..., k, :]) for k in range(4)]

    # w8^k * O_k for k = 0..3, with w8^k unrolled as constants:
    #   k=0: 1
    #   k=1: (1 + s*i)/sqrt2        -> (r - s*i_, r*s + i_)/sqrt2 form below
    #   k=2: s*i
    #   k=3: (-1 + s*i)/sqrt2
    wo = []
    o0r, o0i = o[0]
    wo.append((o0r, o0i))
    o1r, o1i = o[1]
    wo.append((INV_SQRT2 * (o1r - s * o1i), INV_SQRT2 * (o1i + s * o1r)))
    o2r, o2i = o[2]
    wo.append((-s * o2i, s * o2r))
    o3r, o3i = o[3]
    wo.append((INV_SQRT2 * (-o3r - s * o3i), INV_SQRT2 * (-o3i + s * o3r)))

    top_r = [e[k][0] + wo[k][0] for k in range(4)]
    top_i = [e[k][1] + wo[k][1] for k in range(4)]
    bot_r = [e[k][0] - wo[k][0] for k in range(4)]
    bot_i = [e[k][1] - wo[k][1] for k in range(4)]
    return (
        jnp.stack(top_r + bot_r, axis=-2),
        jnp.stack(top_i + bot_i, axis=-2),
    )


BUTTERFLIES = {2: radix_2, 4: radix_4, 8: radix_8}


def apply_stage(xr, xi, r: int, m: int, twr, twi, direction: int):
    """One DIT stage over the last axis: twiddle-multiply then butterfly.

    ``xr/xi``: (..., n) planar data; ``twr/twi``: (r, m) stage twiddles.
    Views the sequence as ``(blocks, r, m)`` — after digit reversal the
    ``r`` sub-transforms of each block are contiguous — and applies
    ``out[b, q, j] = sum_p w_r^{pq} * (w_{rm}^{pj} * in[b, p, j])``.
    """
    n = xr.shape[-1]
    lead = xr.shape[:-1]
    blocks = n // (r * m)
    ar = xr.reshape(*lead, blocks, r, m)
    ai = xi.reshape(*lead, blocks, r, m)
    if m > 1:  # stage 0 twiddles are identically 1
        tr = ar * twr - ai * twi
        ti = ar * twi + ai * twr
    else:
        tr, ti = ar, ai
    s = 1 if direction == SYCLFFT_INVERSE else -1
    out_r, out_i = BUTTERFLIES[r](tr, ti, s)
    return out_r.reshape(*lead, n), out_i.reshape(*lead, n)


# --------------------------------------------------------------------------
# Fused kernel: the paper's ``fft1d`` functor — digit-reversal plus all
# stages in a single kernel, sequence resident in VMEM throughout.
# --------------------------------------------------------------------------

def _fft1d_kernel(n: int, radices: list[int], direction: int,
                  normalize: bool, *refs):
    """Kernel body.

    ``refs`` = (x_re, x_im, perm, tw0_re, tw0_im, ..., o_re, o_im).
    Pallas kernels cannot close over array constants, so the permutation
    and the twiddles arrive as operands — which is in fact the paper's own
    design: "``stage_sizes`` is an array of numbers calculated on the
    host" handed to the kernel via an accessor (Listing 1).
    """
    x_re_ref, x_im_ref, perm_ref = refs[0], refs[1], refs[2]
    tw_refs = refs[3:-2]
    o_re_ref, o_im_ref = refs[-2], refs[-1]

    xr = x_re_ref[...]
    xi = x_im_ref[...]
    # Digit-reversal (the paper's bit-order reversal, Fig. 1) as a gather.
    perm = perm_ref[...]
    xr = jnp.take(xr, perm, axis=-1)
    xi = jnp.take(xi, perm, axis=-1)

    m = 1
    for s_idx, r in enumerate(radices):
        twr = tw_refs[2 * s_idx][...]
        twi = tw_refs[2 * s_idx + 1][...]
        xr, xi = apply_stage(xr, xi, r, m, twr, twi, direction)
        m *= r

    if normalize:
        xr = xr / n
        xi = xi / n
    o_re_ref[...] = xr
    o_im_ref[...] = xi


def make_fft1d(n: int, batch: int = 1, direction: int = SYCLFFT_FORWARD,
               block_batch: int | None = None):
    """Build the fused Pallas FFT callable for a fixed (n, batch, direction).

    Returns ``fn(re, im) -> (re, im)`` over float32 arrays of shape
    ``(batch, n)``.  ``block_batch`` controls the VMEM tile along the
    batch axis (the grid dimension) — the analog of the paper's
    ``WG_FACTOR`` constant that is "automatically determined a priori on
    the host".
    """
    radices = plan_radices(n)
    perm = input_permutation(n)
    normalize = direction == SYCLFFT_INVERSE
    if block_batch is None:
        block_batch = default_block_batch(n, batch)
    if batch % block_batch:
        raise ValueError(f"batch {batch} not divisible by block_batch {block_batch}")

    kernel = functools.partial(_fft1d_kernel, n, radices, direction, normalize)

    # Twiddles for every stage, shaped (r, m); fed as operands so the
    # kernel itself stays architecture-agnostic (paper §4: host computes
    # stage data, kernel consumes it).
    tws = []
    m = 1
    for r in radices:
        twr, twi = stage_twiddles(r, m, direction)
        tws.extend([twr, twi])
        m *= r

    data_spec = pl.BlockSpec((block_batch, n), lambda i: (i, 0))
    perm_spec = pl.BlockSpec((n,), lambda i: (0,))
    tw_specs = [pl.BlockSpec(t.shape, lambda i: (0, 0)) for t in tws]

    call = pl.pallas_call(
        kernel,
        grid=(batch // block_batch,),
        in_specs=[data_spec, data_spec, perm_spec, *tw_specs],
        out_specs=[data_spec, data_spec],
        out_shape=[
            jax.ShapeDtypeStruct((batch, n), jnp.float32),
            jax.ShapeDtypeStruct((batch, n), jnp.float32),
        ],
        interpret=True,
    )

    def fn(re, im):
        out_re, out_im = call(
            re, im, jnp.asarray(perm), *[jnp.asarray(t) for t in tws]
        )
        return out_re, out_im

    return fn


def default_block_batch(n: int, batch: int) -> int:
    """The WG_FACTOR analog: pick the largest batch tile whose planar
    working set (in + out + temp, 4 planes of f32) stays under a
    conservative VMEM budget of 4 MiB."""
    budget = 4 * 1024 * 1024
    per_seq = 4 * n * 4  # 4 f32 planes per sequence
    tile = max(1, min(batch, budget // per_seq))
    while batch % tile:
        tile -= 1
    return tile


# --------------------------------------------------------------------------
# Staged kernels: one pallas_call per FFT stage.  This is the ablation
# variant — it reproduces the paper's *launch-overhead amplification*
# (one SYCL kernel launch per operation) and is also what the Rust
# multi-kernel pipeline executes artifact-by-artifact.
# --------------------------------------------------------------------------

def make_bitrev(n: int, batch: int = 1):
    """Standalone digit-reversal permutation kernel."""
    perm = input_permutation(n)

    def kernel(x_re_ref, x_im_ref, perm_ref, o_re_ref, o_im_ref):
        p = perm_ref[...]
        o_re_ref[...] = jnp.take(x_re_ref[...], p, axis=-1)
        o_im_ref[...] = jnp.take(x_im_ref[...], p, axis=-1)

    spec = pl.BlockSpec((batch, n), lambda: (0, 0))
    perm_spec = pl.BlockSpec((n,), lambda: (0,))
    call = pl.pallas_call(
        kernel,
        in_specs=[spec, spec, perm_spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((batch, n), jnp.float32)] * 2,
        interpret=True,
    )
    return lambda re, im: call(re, im, jnp.asarray(perm))


def make_stage(n: int, r: int, m: int, batch: int = 1,
               direction: int = SYCLFFT_FORWARD):
    """Standalone radix-``r`` stage kernel (assumes digit-reversed input
    and ``m`` already-combined sub-transforms)."""
    twr, twi = stage_twiddles(r, m, direction)

    def kernel(x_re_ref, x_im_ref, twr_ref, twi_ref, o_re_ref, o_im_ref):
        xr, xi = apply_stage(
            x_re_ref[...], x_im_ref[...], r, m, twr_ref[...], twi_ref[...],
            direction,
        )
        o_re_ref[...] = xr
        o_im_ref[...] = xi

    spec = pl.BlockSpec((batch, n), lambda: (0, 0))
    tw_spec = pl.BlockSpec((r, m), lambda: (0, 0))
    call = pl.pallas_call(
        kernel,
        in_specs=[spec, spec, tw_spec, tw_spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((batch, n), jnp.float32)] * 2,
        interpret=True,
    )
    return lambda re, im: call(re, im, jnp.asarray(twr), jnp.asarray(twi))


def fft1d_staged(re, im, direction: int = SYCLFFT_FORWARD):
    """Full FFT as a chain of standalone kernels (bitrev + one per stage)."""
    batch, n = re.shape
    out_re, out_im = make_bitrev(n, batch)(re, im)
    m = 1
    for r in plan_radices(n):
        out_re, out_im = make_stage(n, r, m, batch, direction)(out_re, out_im)
        m *= r
    if direction == SYCLFFT_INVERSE:
        out_re = out_re / n
        out_im = out_im / n
    return out_re, out_im


def normalize_inverse(re, im, n: int):
    """The 1/N normalisation of Eqn. (2), exposed for the staged pipeline
    (the Rust runtime applies it as a final scaling kernel)."""
    return re / n, im / n
