//! FFT-based convolution: forward transforms through the AOT artifacts,
//! a pointwise product on the host, and the inverse artifact — the
//! classic "fast filtering" application, verified against direct
//! convolution.
//!
//! ```sh
//! make artifacts && cargo run --release --example fft_convolution
//! ```

use anyhow::Result;
use syclfft::fft::Direction;
use syclfft::plan::Variant;
use syclfft::runtime::FftLibrary;

fn main() -> Result<()> {
    let lib = FftLibrary::open(std::path::Path::new("artifacts"))?;
    let n = 1024; // circular convolution length (power of two artifact)

    // A square pulse convolved with a decaying filter.
    let mut sig = vec![0.0f32; n];
    for s in sig.iter_mut().take(200).skip(100) {
        *s = 1.0;
    }
    let mut ker = vec![0.0f32; n];
    for (j, k) in ker.iter_mut().enumerate().take(32) {
        *k = (-(j as f32) / 8.0).exp();
    }

    let zeros = vec![0.0f32; n];
    // Forward transforms through the portable artifact.
    let (sr, si) = lib.execute(Variant::Pallas, Direction::Forward, &sig, &zeros, 1)?;
    let (kr, ki) = lib.execute(Variant::Pallas, Direction::Forward, &ker, &zeros, 1)?;

    // Pointwise complex product on the host.
    let mut pr = vec![0.0f32; n];
    let mut pi = vec![0.0f32; n];
    for j in 0..n {
        pr[j] = sr[j] * kr[j] - si[j] * ki[j];
        pi[j] = sr[j] * ki[j] + si[j] * kr[j];
    }

    // Inverse transform: the convolution theorem.
    let (conv, _) = lib.execute(Variant::Pallas, Direction::Inverse, &pr, &pi, 1)?;

    // Direct circular convolution for verification.
    let mut want = vec![0.0f32; n];
    for i in 0..n {
        for (j, &k) in ker.iter().enumerate().take(32) {
            want[(i + j) % n] += sig[i] * k;
        }
    }

    let scale: f32 = want.iter().map(|v| v.abs()).fold(1.0, f32::max);
    let max_err = conv
        .iter()
        .zip(&want)
        .map(|(&g, &w)| (g - w).abs())
        .fold(0.0f32, f32::max)
        / scale;

    println!("circular convolution, n = {n}");
    println!("pulse [100, 200) * exp(-j/8) kernel (support 32)");
    println!("edge response around the pulse onset:");
    for i in 98..106 {
        println!("  y[{i}] = {:>8.4}   (direct: {:>8.4})", conv[i], want[i]);
    }
    println!("max relative error vs direct convolution: {max_err:.3e}");
    assert!(max_err < 1e-4, "convolution must match the direct sum");
    println!("convolution theorem verified through the AOT artifacts ✓");
    Ok(())
}
