//! End-to-end paper reproduction driver.
//!
//! Exercises the full stack — AOT artifacts through the PJRT runtime,
//! the serving coordinator, the simulated five-platform testbed, and the
//! statistics machinery — regenerating every table and figure of the
//! paper plus the serving-layer ablation.  Writes text + CSV reports to
//! `artifacts/repro_report/` and a summary to stdout.
//!
//! ```sh
//! make artifacts && cargo run --release --example paper_repro
//! ```

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Result};
use syclfft::coordinator::{Coordinator, CoordinatorConfig, FftRequest};
use syclfft::fft::Direction;
use syclfft::harness::ALL_EXPERIMENTS;
use syclfft::plan::Variant;
use syclfft::runtime::FftLibrary;

fn main() -> Result<()> {
    let t0 = Instant::now();
    let out_dir = Path::new("artifacts/repro_report");
    std::fs::create_dir_all(out_dir)?;

    // ---- real artifacts on the host PJRT runtime ------------------------
    let lib = match FftLibrary::open(Path::new("artifacts")) {
        Ok(l) => Some(l),
        Err(e) => {
            eprintln!("note: running simulated columns only ({e})");
            None
        }
    };

    // ---- every table and figure -----------------------------------------
    let iters = 1000; // the paper's §6.1 protocol
    let mut full_report = String::new();
    for e in ALL_EXPERIMENTS {
        println!("running {} ...", e.id());
        let text = e.run(lib.as_ref(), iters, Some(out_dir))?;
        full_report.push_str(&text);
        full_report.push('\n');
    }

    // ---- the serving-layer ablation (beyond the paper) -------------------
    println!("running serving ablation ...");
    full_report.push_str(&serving_ablation()?);

    std::fs::write(out_dir.join("report.txt"), &full_report)?;
    println!("{full_report}");
    println!(
        "full reproduction complete in {:.1} s — report + CSVs in {}",
        t0.elapsed().as_secs_f64(),
        out_dir.display()
    );
    Ok(())
}

/// Dynamic batching vs one-launch-per-request: quantifies how much of
/// the paper's launch-overhead penalty a serving layer can claw back.
fn serving_ablation() -> Result<String> {
    let mut out = String::from(
        "Serving ablation — dynamic batching vs per-request launches\n\
         ===========================================================\n",
    );
    // Small transform: compute is tiny, dispatch dominates — the regime
    // the paper identifies as launch-bound (§6.1).
    let n = 64;
    let requests = 128;

    for (label, min_fill) in [("batched (fill>=2)", 2usize), ("unbatched (singletons)", usize::MAX)]
    {
        let mut cfg = CoordinatorConfig::new("artifacts");
        cfg.batcher.min_fill = min_fill;
        let coord = Coordinator::spawn(cfg)?;
        let handle = coord.handle();

        // Warm-up: trigger compilation of both batch-1 and batch-8
        // executables before the timed section (the paper discards the
        // first, compile-bearing launch too).
        let warm: Vec<_> = (0..8)
            .map(|_| {
                handle.submit(FftRequest::new(
                    Variant::Pallas,
                    Direction::Forward,
                    vec![0.5f32; n],
                    vec![0.0f32; n],
                ))
            })
            .collect::<Result<_>>()?;
        for rx in warm {
            let _ = rx.recv()?.map_err(|e| anyhow!(e))?;
        }
        let _ = handle.call(FftRequest::new(
            Variant::Pallas,
            Direction::Forward,
            vec![0.5f32; n],
            vec![0.0f32; n],
        ))?;

        let t0 = Instant::now();
        let rxs: Vec<_> = (0..requests)
            .map(|i| {
                let re: Vec<f32> = (0..n).map(|j| ((i + j) as f32 * 0.01).sin()).collect();
                handle.submit(FftRequest::new(
                    Variant::Pallas,
                    Direction::Forward,
                    re,
                    vec![0.0f32; n],
                ))
            })
            .collect::<Result<_>>()?;
        let mut members = 0usize;
        for rx in rxs {
            members += rx.recv()?.map_err(|e| anyhow!(e))?.batch_members;
        }
        let wall = t0.elapsed().as_secs_f64() * 1e6;
        out.push_str(&format!(
            "{label:<24} {requests} reqs, n={n}: {:>9.0} us wall, {:>6.1} us/req, mean occupancy {:.2}\n",
            wall,
            wall / requests as f64,
            members as f64 / requests as f64
        ));
    }
    out.push_str(
        "(occupancy > 1 amortises one PJRT dispatch across several requests — \
         the serving answer to the paper's launch-overhead finding)\n",
    );
    Ok(out)
}
