//! Spectral analysis through the serving path: submit noisy multi-tone
//! signals to the coordinator concurrently, let the dynamic batcher
//! amortise launches, and detect the tones from the returned spectra.
//!
//! This is the workload the paper's intro motivates (condition
//! monitoring / fault analysis: find the machine's vibration lines in a
//! noisy sensor trace).
//!
//! ```sh
//! make artifacts && cargo run --release --example spectral_analysis
//! ```

use anyhow::{anyhow, Result};
use syclfft::coordinator::{Coordinator, CoordinatorConfig, FftRequest};
use syclfft::fft::{to_planar, Direction};
use syclfft::plan::Variant;
use syclfft::signal::{add_noise, multi_tone, XorShift64};

/// Find the `count` largest spectral peaks in the positive-frequency
/// half, ignoring bins adjacent to already-claimed peaks.
fn top_peaks(mag: &[f64], count: usize) -> Vec<usize> {
    let half = mag.len() / 2;
    let mut order: Vec<usize> = (1..half).collect();
    order.sort_by(|&a, &b| mag[b].partial_cmp(&mag[a]).unwrap());
    let mut peaks: Vec<usize> = Vec::new();
    for k in order {
        if peaks.iter().all(|&p| (p as isize - k as isize).unsigned_abs() > 2) {
            peaks.push(k);
            if peaks.len() == count {
                break;
            }
        }
    }
    peaks.sort_unstable();
    peaks
}

fn main() -> Result<()> {
    let n = 2048;
    let coord = Coordinator::spawn(CoordinatorConfig::new("artifacts"))?;
    let handle = coord.handle();

    // 16 sensors, each carrying the same two machine lines (bins 100 and
    // 341) plus an individual harmonic and Gaussian noise.
    let mut rng = XorShift64::new(2022);
    let sensors = 16usize;
    let mut expected: Vec<Vec<usize>> = Vec::new();
    let mut receivers = Vec::new();
    for s in 0..sensors {
        let own = 400 + 37 * s;
        let mut sig = multi_tone(n, &[(100, 1.0), (341, 0.8), (own, 0.6)]);
        add_noise(&mut sig, 0.05, &mut rng);
        expected.push(vec![100, 341, own]);
        let (re, im) = to_planar(&sig);
        receivers.push(handle.submit(FftRequest::new(
            Variant::Pallas,
            Direction::Forward,
            re,
            im,
        ))?);
    }

    let mut correct = 0;
    let mut batched = 0usize;
    for (s, rx) in receivers.into_iter().enumerate() {
        let resp = rx.recv()?.map_err(|e| anyhow!(e))?;
        batched += resp.batch_members;
        let mag: Vec<f64> = resp
            .re
            .iter()
            .zip(&resp.im)
            .map(|(&r, &i)| ((r as f64).powi(2) + (i as f64).powi(2)).sqrt())
            .collect();
        let peaks = top_peaks(&mag, 3);
        let mut want = expected[s].clone();
        want.sort_unstable();
        let ok = peaks == want;
        if ok {
            correct += 1;
        }
        println!(
            "sensor {s:>2}: peaks {:?} {} (launch shared by {} request(s))",
            peaks,
            if ok { "✓" } else { "✗" },
            resp.batch_members
        );
    }
    println!("\ndetected all tones on {correct}/{sensors} sensors");
    println!("mean batch occupancy: {:.2}", batched as f64 / sensors as f64);
    println!("\n{}", handle.metrics_table()?);
    assert_eq!(correct, sensors, "all sensors must resolve their tones");
    Ok(())
}
