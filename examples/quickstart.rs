//! Quickstart: load the AOT-compiled portable FFT, transform the paper's
//! workload f(x) = x, and inspect the spectrum.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use syclfft::fft::{Direction, MixedRadixPlan};
use syclfft::plan::{Descriptor, Variant};
use syclfft::runtime::FftLibrary;
use syclfft::signal;

fn main() -> Result<()> {
    // 1. Open the compiled artifact library (HLO text -> PJRT).
    let lib = FftLibrary::open(std::path::Path::new("artifacts"))?;
    println!(
        "library open: {} artifacts on {} ({} device(s))",
        lib.manifest().len(),
        lib.runtime().platform_name(),
        lib.runtime().device_count()
    );

    // 2. The paper's evaluation input: f(x) = x over 2^11 points (§6).
    let n = 2048;
    let re: Vec<f32> = (0..n).map(|i| i as f32).collect();
    let im = vec![0.0f32; n];

    // 3. Run the portable (Pallas) kernel — one compiled launch.
    let exe = lib.get(&Descriptor::new(Variant::Pallas, n, 1, Direction::Forward))?;
    let ((out_re, out_im), us) = exe.execute_timed(lib.runtime(), &re, &im)?;
    println!("forward FFT of f(x)=x, n={n}: {us:.1} us total");
    println!("X[0] (DC) = {:.0}  (expected n(n-1)/2 = {})", out_re[0], n * (n - 1) / 2);
    for k in 1..4 {
        println!("X[{k}] = ({:.2}, {:.2})", out_re[k], out_im[k]);
    }

    // 4. Cross-check against the native Rust library (the in-process
    //    "vendor" comparator).
    let want = MixedRadixPlan::new(n, Direction::Forward).transform(&signal::ramp(n));
    let scale: f32 = want.iter().map(|z| z.abs()).fold(1.0, f32::max);
    let dev = out_re
        .iter()
        .zip(&out_im)
        .zip(&want)
        .map(|((&r, &i), w)| ((r - w.re).abs().max((i - w.im).abs())) / scale)
        .fold(0.0f32, f32::max);
    println!("max relative deviation vs native Rust FFT: {dev:.3e}");

    // 5. Round-trip through the inverse artifact.
    let inv = lib.get(&Descriptor::new(Variant::Pallas, n, 1, Direction::Inverse))?;
    let (back_re, _back_im) = inv.execute(lib.runtime(), &out_re, &out_im)?;
    let rt_err = back_re
        .iter()
        .enumerate()
        .map(|(i, &v)| (v - i as f32).abs())
        .fold(0.0f32, f32::max);
    println!("iFFT(FFT(x)) max abs error: {rt_err:.3e}");
    Ok(())
}
